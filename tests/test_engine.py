"""Serving-engine integration: backend agreement, prefix reuse, paging."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine
from repro.serving.kv_cache import PageAllocator


def _engine(arch, params=None, cfg=None, **kw):
    cfg = cfg or smoke_config(arch)
    params = params if params is not None else T.init_params(
        cfg, jax.random.PRNGKey(0))
    defaults = dict(page_size=16, num_pages=512, max_q=8, temperature=0.0)
    defaults.update(kw)
    return DecodeEngine(cfg, params, **defaults), cfg, params


def _doc_qa_prompts(n=3, doc_len=48, q_len=3):
    doc = list(range(10, 10 + doc_len))
    return [doc + [100 + 3 * i + j for j in range(q_len)] for i in range(n)]


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma-2b"])
def test_backends_agree(arch):
    """Full decode loop through every registered backend (the oracle
    ``ref`` included) must produce identical greedy tokens."""
    from repro.kernels import registry
    prompts = _doc_qa_prompts()
    outs = {}
    for backend in registry.names():
        eng, cfg, params = _engine(arch, backend=backend)
        for p in prompts:
            eng.add_request(p, max_new=5)
        outs[backend] = eng.run(8)
    expect = outs["codec-xla"]
    for backend, got in outs.items():
        assert got == expect, backend


def test_engine_matches_dense_decode():
    """Engine greedy decode == dense-cache prefill+decode reference."""
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(10, 10 + 37))
    eng, _, _ = _engine("qwen2.5-14b", params=params, cfg=cfg)
    eng.add_request(prompt, max_new=6)
    gen_engine = eng.run(8)[0]

    toks = jnp.asarray(prompt)[None]
    logits, cache, clen = T.prefill(params, cfg, toks, max_len=64)
    gen_ref = []
    nxt = int(jnp.argmax(logits[0]))
    for _ in range(6):
        gen_ref.append(nxt)
        logits, cache = T.decode_step(params, cfg,
                                      jnp.asarray([[nxt]]), cache, clen)
        clen = clen + 1
        nxt = int(jnp.argmax(logits[0]))
    assert gen_engine == gen_ref


def test_sliding_window_arch_backends_agree():
    """gemma3 (5:1 local:global) exercises the per-window plans."""
    prompts = _doc_qa_prompts(2, doc_len=64, q_len=2)
    outs = {}
    for backend in ("codec-xla", "flash", "hydragen"):
        eng, cfg, params = _engine("gemma3-1b", backend=backend)
        for p in prompts:
            eng.add_request(p, max_new=4)
        outs[backend] = eng.run(6)
    assert outs["codec-xla"] == outs["flash"] == outs["hydragen"]


def test_hybrid_mamba_engine():
    """jamba: mamba state caching + attention paging coexist."""
    prompts = _doc_qa_prompts(2, doc_len=32, q_len=2)
    eng, cfg, params = _engine("jamba-v0.1-52b", backend="codec-xla")
    for p in prompts:
        eng.add_request(p, max_new=4)
    outs = eng.run(6)
    assert all(len(v) == 4 for v in outs.values())
    # shared prefix nodes cached SSM states
    shared = [n for n in eng.forest.real_nodes() if len(n.requests) > 1]
    assert shared and any("ssm" in n.meta for n in shared)


def test_prefix_reuse_skips_prefill_work():
    eng, cfg, params = _engine("qwen2.5-14b")
    doc = list(range(10, 74))       # 64 tokens = 4 pages
    eng.add_request(doc + [100, 101], max_new=2)
    t1 = eng.stats["prefill_tokens"]
    eng.add_request(doc + [200, 201], max_new=2)
    t2 = eng.stats["prefill_tokens"] - t1
    assert t1 == 66
    assert t2 == 2   # only the private question is recomputed


def test_release_frees_pages():
    eng, cfg, params = _engine("qwen2.5-14b")
    free0 = eng.pool.allocator.num_free
    prompts = _doc_qa_prompts(2)
    rids = [eng.add_request(p, max_new=2) for p in prompts]
    eng.run(4)
    used = free0 - eng.pool.allocator.num_free
    assert used > 0
    for r in rids:
        eng.release(r)
    assert eng.pool.allocator.num_free == free0


def test_replan_interval_and_plan_reuse():
    eng, cfg, params = _engine("qwen2.5-14b", replan_interval=2)
    for p in _doc_qa_prompts(2):
        eng.add_request(p, max_new=6)
    eng.run(8)
    # replans happen at the interval cadence (plus page-boundary events)
    assert eng.stats["replans"] >= 3
    assert eng.stats["steps"] == 6


def test_page_allocator_refcounts():
    a = PageAllocator(8)
    pages = a.alloc(4)
    a.retain(pages[:2])
    a.release(pages)            # refs: 2 pages still held
    assert a.num_free == 8 - 2
    a.release(pages[:2])
    assert a.num_free == 8


def test_staggered_finish_and_late_arrivals():
    """Requests finishing at different times + continuous batching:
    plans must be rebuilt over the ACTIVE set only (regression: finished
    requests lingering in node.requests broke row indexing)."""
    doc = list(range(10, 74))
    outs = {}
    for backend in ("codec-xla", "flash"):
        eng, cfg, params = _engine("qwen2.5-14b", backend=backend)
        eng.add_request(doc + [1, 2], max_new=3)    # finishes early
        eng.add_request(doc + [3, 4], max_new=9)
        eng.step(); eng.step()
        eng.add_request(doc + [5, 6], max_new=4)    # arrives mid-decode
        eng.run(12)
        outs[backend] = {r: q.generated for r, q in eng.requests.items()}
    assert outs["codec-xla"] == outs["flash"]
    lens = sorted(len(v) for v in outs["flash"].values())
    assert lens == [3, 4, 9]


def test_engine_oom_raises():
    eng, cfg, params = _engine("qwen2.5-14b", num_pages=4)
    with pytest.raises(MemoryError):
        eng.add_request(list(range(1000)), max_new=2)
