"""Serving-engine integration: backend agreement, prefix reuse, paging."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine
from repro.serving.kv_cache import PageAllocator


def _engine(arch, params=None, cfg=None, **kw):
    cfg = cfg or smoke_config(arch)
    params = params if params is not None else T.init_params(
        cfg, jax.random.PRNGKey(0))
    defaults = dict(page_size=16, num_pages=512, max_q=8, temperature=0.0)
    defaults.update(kw)
    return DecodeEngine(cfg, params, **defaults), cfg, params


def _doc_qa_prompts(n=3, doc_len=48, q_len=3):
    doc = list(range(10, 10 + doc_len))
    return [doc + [100 + 3 * i + j for j in range(q_len)] for i in range(n)]


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma-2b"])
def test_backends_agree(arch):
    """Full decode loop through every registered backend (the oracle
    ``ref`` included) must produce identical greedy tokens."""
    from repro.kernels import registry
    prompts = _doc_qa_prompts()
    outs = {}
    for backend in registry.names():
        eng, cfg, params = _engine(arch, backend=backend)
        for p in prompts:
            eng.add_request(p, max_new=5)
        outs[backend] = eng.run(8)
    expect = outs["codec-xla"]
    for backend, got in outs.items():
        assert got == expect, backend


def test_engine_matches_dense_decode():
    """Engine greedy decode == dense-cache prefill+decode reference."""
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(10, 10 + 37))
    eng, _, _ = _engine("qwen2.5-14b", params=params, cfg=cfg)
    eng.add_request(prompt, max_new=6)
    gen_engine = eng.run(8)[0]

    toks = jnp.asarray(prompt)[None]
    logits, cache, clen = T.prefill(params, cfg, toks, max_len=64)
    gen_ref = []
    nxt = int(jnp.argmax(logits[0]))
    for _ in range(6):
        gen_ref.append(nxt)
        logits, cache = T.decode_step(params, cfg,
                                      jnp.asarray([[nxt]]), cache, clen)
        clen = clen + 1
        nxt = int(jnp.argmax(logits[0]))
    assert gen_engine == gen_ref


def test_sliding_window_arch_backends_agree():
    """gemma3 (5:1 local:global) exercises the per-window plans."""
    prompts = _doc_qa_prompts(2, doc_len=64, q_len=2)
    outs = {}
    for backend in ("codec-xla", "flash", "hydragen"):
        eng, cfg, params = _engine("gemma3-1b", backend=backend)
        for p in prompts:
            eng.add_request(p, max_new=4)
        outs[backend] = eng.run(6)
    assert outs["codec-xla"] == outs["flash"] == outs["hydragen"]


def test_hybrid_mamba_engine():
    """jamba: mamba state caching + attention paging coexist."""
    prompts = _doc_qa_prompts(2, doc_len=32, q_len=2)
    eng, cfg, params = _engine("jamba-v0.1-52b", backend="codec-xla")
    for p in prompts:
        eng.add_request(p, max_new=4)
    outs = eng.run(6)
    assert all(len(v) == 4 for v in outs.values())
    # shared prefix nodes cached SSM states
    shared = [n for n in eng.forest.real_nodes() if len(n.requests) > 1]
    assert shared and any("ssm" in n.meta for n in shared)


def test_prefix_reuse_skips_prefill_work():
    eng, cfg, params = _engine("qwen2.5-14b")
    doc = list(range(10, 74))       # 64 tokens = 4 pages
    eng.add_request(doc + [100, 101], max_new=2)
    t1 = eng.stats["prefill_tokens"]
    eng.add_request(doc + [200, 201], max_new=2)
    t2 = eng.stats["prefill_tokens"] - t1
    assert t1 == 66
    assert t2 == 2   # only the private question is recomputed


def test_release_frees_pages():
    eng, cfg, params = _engine("qwen2.5-14b")
    free0 = eng.pool.allocator.num_free
    prompts = _doc_qa_prompts(2)
    rids = [eng.add_request(p, max_new=2) for p in prompts]
    eng.run(4)
    used = free0 - eng.pool.allocator.num_free
    assert used > 0
    for r in rids:
        eng.release(r)
    assert eng.pool.allocator.num_free == free0


def test_replan_interval_and_plan_reuse():
    eng, cfg, params = _engine("qwen2.5-14b", replan_interval=2)
    for p in _doc_qa_prompts(2):
        eng.add_request(p, max_new=6)
    eng.run(8)
    # replans happen at the interval cadence (plus page-boundary events)
    assert eng.stats["replans"] >= 3
    assert eng.stats["steps"] == 6


def test_page_allocator_refcounts():
    a = PageAllocator(8)
    pages = a.alloc(4)
    a.retain(pages[:2])
    a.release(pages)            # refs: 2 pages still held
    assert a.num_free == 8 - 2
    a.release(pages[:2])
    assert a.num_free == 8


def test_page_allocator_unknown_page_is_value_error():
    """Regression: release/retain of a never-allocated (or double-freed)
    page id must raise a clear ValueError, not KeyError."""
    a = PageAllocator(4)
    with pytest.raises(ValueError):
        a.release([0])
    with pytest.raises(ValueError):
        a.retain([3])
    pages = a.alloc(2)
    a.release(pages)
    with pytest.raises(ValueError):
        a.release(pages)        # double free
    a.check()


def test_page_allocator_watermarks_and_check():
    a = PageAllocator(6)
    p1 = a.alloc(4)
    assert (a.num_used, a.peak_used, a.total_allocs) == (4, 4, 4)
    a.release(p1[:3])
    p2 = a.alloc(2)
    assert a.peak_used == 4 and a.num_used == 3
    assert abs(a.occupancy() - 3 / 6) < 1e-12
    a.check()
    with pytest.raises(MemoryError):
        a.alloc(a.num_free + 1)
    a.release(p1[3:])
    a.release(p2)
    assert a.num_free == 6
    a.check()


def test_exhaustion_preempts_and_leaves_no_leaks():
    """Pool exhaustion -> preemption path: an undersized pool completes
    all requests, and after releasing everything no pages are leaked and
    no refcounts dangle (with eviction in the mix)."""
    eng, cfg, params = _engine("qwen2.5-14b", page_size=8, num_pages=9)
    doc = list(range(10, 58))
    for i in range(4):
        eng.add_request(doc + [100 + 3 * i + j for j in range(3)],
                        max_new=6)
    outs = eng.run(64)
    assert all(len(v) == 6 for v in outs.values())
    assert eng.stats["preempted"] >= 1
    assert eng.stats["recompute_tokens"] >= 1
    assert eng.pool.allocator.peak_used == 9
    for r in list(eng.requests):
        eng.release(r)
    assert eng.pool.allocator.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}


def test_release_of_preempted_request_unpins_cache():
    """Releasing a request while it waits (preempted, holding pins on the
    shared prefix) must unwind the pins so nothing leaks."""
    eng, cfg, params = _engine("qwen2.5-14b", page_size=8, num_pages=64)
    doc = list(range(10, 42))                   # 32 tokens, page-aligned
    r0 = eng.add_request(doc + [1, 2], max_new=4)
    r1 = eng.add_request(doc + [3, 4], max_new=4)
    eng.step()
    eng._preempt(r1)
    assert eng.requests[r1].state == "waiting"
    assert eng.requests[r1].pinned              # shared doc node pinned
    eng.release(r1)                             # cancelled before resuming
    outs = eng.run(16)
    assert len(outs[r0]) == 4 and r1 not in outs
    eng.release(r0)
    assert eng.pool.allocator.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}


def test_multiply_pinned_nodes_are_reclaimable():
    """A cache node pinned by TWO waiting requests must still be
    reclaimable under pressure (one pin dropped per holder until the
    last drop frees the pages)."""
    eng, cfg, params = _engine("qwen2.5-14b", page_size=8, num_pages=10)
    doc = list(range(10, 42))                   # 4 pages
    r0 = eng.add_request(doc + [1, 2], max_new=4)
    r1 = eng.add_request(doc + [3, 4], max_new=4)
    eng.step()
    eng._preempt(r0)
    eng._preempt(r1)
    shared = [n for n in eng.forest.real_nodes()
              if n.meta.get("pins", 0) > 0]
    assert shared and shared[0].meta["pins"] == 2
    assert eng.pool.num_free == 6              # 4 doc pages still pinned
    # regression: one reclamation call (what decode growth issues under
    # pressure) must shed both holders' pins and free the doc pages —
    # previously a pins==2 node was skipped and the pool deadlocked
    assert eng._reclaim_one(set(), allow_preempt=False)
    assert eng.pool.num_free == eng.pool.num_pages
    assert shared[0].id not in eng.forest.nodes
    assert not eng.requests[r0].pinned and not eng.requests[r1].pinned
    # both holders resume with a full recompute and finish identically
    outs = eng.run(64)
    assert all(len(outs[r]) == 4 for r in (r0, r1))
    assert eng.stats["recompute_tokens"] > 0
    for r in list(eng.requests):
        eng.release(r)
    assert eng.pool.allocator.num_free == eng.pool.num_pages
    eng.pool.allocator.check()


def test_max_running_cap_does_not_destroy_cache():
    """A max_running rejection is a capacity cap, not memory pressure:
    it must not reclaim finished-request KV (the radix cache)."""
    eng, cfg, params = _engine("qwen2.5-14b", page_size=8, num_pages=512,
                               max_running=1)
    doc = list(range(10, 42))
    rA = eng.add_request(doc + [1], max_new=2)
    eng.run(8)                                  # A done, KV stays cached
    rB = eng.add_request(list(range(200, 248)), max_new=4)
    before = eng.stats["prefill_tokens"]
    rC = eng.add_request(doc + [2], max_new=2)  # blocked by the cap only
    assert eng.requests[rC].state == "waiting"
    assert eng.stats["reclaimed"] == 0
    eng.run(16)
    assert eng.stats["reclaimed"] == 0
    assert len(eng.requests[rC].generated) == 2
    # C reused A's cached doc: only its private tail was prefilled
    assert eng.stats["prefill_tokens"] - before == 1


def test_plan_rebuilt_exactly_on_lifecycle_events():
    """The frozen plan is reused across steps and rebuilt exactly when a
    leaf crosses a page boundary, batch membership changes, or a request
    is evicted (counted via the engine's rebuild counter).

    A leaf crosses when its pre-append length is page-aligned; prompt
    lengths are chosen so both leaves cross on the same steps.
    """
    ps = 4
    eng, cfg, params = _engine("qwen2.5-14b", page_size=ps, num_pages=256,
                               backend="codec-xla")
    r0 = eng.add_request(list(range(10, 20)), max_new=32)  # leaf len 10
    assert eng.plan_rebuilds == 0          # plans are built lazily
    expected = 0
    for s, pre_len in enumerate(range(10, 16)):
        eng.step()
        expected += 1 if (s == 0 or pre_len % ps == 0) else 0
        assert eng.plan_rebuilds == expected, f"step {s}"
    # membership change: a new request joins (radix split of r0's leaf at
    # the 8-token boundary; r1's private leaf = 4 tokens, page-aligned
    # with r0's leaf (len 16), so they keep crossing on the same steps)
    r1 = eng.add_request(list(range(10, 20)) + [77, 78], max_new=32)
    eng.step()
    expected += 1
    assert eng.plan_rebuilds == expected
    # in-page growth reuses the plan for 3 steps, then both leaves cross
    for k, pre_len in enumerate(range(17, 21)):
        eng.step()
        expected += 1 if pre_len % ps == 0 else 0
        assert eng.plan_rebuilds == expected, f"growth step {k}"
    # eviction invalidates the plan: the victim leaves the batch and
    # resumes in the same engine step with a fresh private leaf
    eng._preempt(r0)
    assert eng.requests[r0].state == "waiting"
    eng.step()
    expected += 1
    assert eng.plan_rebuilds == expected
    # the workload still completes exactly
    eng.run(64)
    assert len(eng.requests[r0].generated) == 32
    assert len(eng.requests[r1].generated) == 32


def test_staggered_finish_and_late_arrivals():
    """Requests finishing at different times + continuous batching:
    plans must be rebuilt over the ACTIVE set only (regression: finished
    requests lingering in node.requests broke row indexing)."""
    doc = list(range(10, 74))
    outs = {}
    for backend in ("codec-xla", "flash"):
        eng, cfg, params = _engine("qwen2.5-14b", backend=backend)
        eng.add_request(doc + [1, 2], max_new=3)    # finishes early
        eng.add_request(doc + [3, 4], max_new=9)
        eng.step(); eng.step()
        eng.add_request(doc + [5, 6], max_new=4)    # arrives mid-decode
        eng.run(12)
        outs[backend] = {r: q.generated for r, q in eng.requests.items()}
    assert outs["codec-xla"] == outs["flash"]
    lens = sorted(len(v) for v in outs["flash"].values())
    assert lens == [3, 4, 9]


def test_engine_oom_raises():
    eng, cfg, params = _engine("qwen2.5-14b", num_pages=4)
    with pytest.raises(MemoryError):
        eng.add_request([i % 250 for i in range(1000)], max_new=2)


def test_oversized_prompt_queues_under_chunked_prefill():
    """Regression: admission raised MemoryError whenever a prompt's
    TOTAL page need exceeded the pool, even though chunked prefill only
    needs one chunk + tail resident at a time.  Only the working set
    decides servability; larger prompts stay queued."""
    eng, cfg, params = _engine("qwen2.5-14b", num_pages=8,
                               prefill_chunk=16)
    rid = eng.add_request([i % 250 for i in range(1000)],
                          max_new=2)                      # 63 total pages
    assert eng.requests[rid].state == "waiting"           # queued, no raise
    eng.step()
    assert eng.requests[rid].state == "waiting"
    # whole-prompt prefill (no chunking) still fails fast
    with pytest.raises(MemoryError):
        _engine("qwen2.5-14b", num_pages=8)[0].add_request(
            [i % 250 for i in range(1000)], max_new=2)


def test_split_while_pinned_keeps_both_halves_protected():
    """Regression: splitting a pinned prefix node dropped the pin on
    the lower half, so releasing the sharing request freed KV that a
    preempted waiter's admission estimate still counted on."""
    eng, cfg, params = _engine("qwen2.5-14b", page_size=8, num_pages=64)
    doc = list(range(10, 42))                   # 32 tokens = 4 pages
    r0 = eng.add_request(doc + [1, 2], max_new=4)
    r1 = eng.add_request(doc + [3, 4], max_new=4)
    eng.step()
    eng._preempt(r1)                 # r1 waits, pinning the shared doc
    eng.admission.remove(r1)         # hold it out so it cannot resume yet
    assert eng.requests[r1].pinned
    # r2 shares only half the doc -> splits the pinned node
    r2 = eng.add_request(doc[:16] + [5, 6], max_new=2)
    eng.run(16)
    assert eng.requests[r0].done and eng.requests[r2].done
    eng.release(r0)
    eng.release(r2)
    # the whole 4-page pinned span must survive the releases (pre-fix
    # the unpinned lower half was freed: only 2 pages remained)
    pinned = [n for n in eng.forest.real_nodes()
              if n.meta.get("pins", 0) > 0]
    assert sum(len(n.page_ids) for n in pinned) == 4
    # the waiter's pin list covers every pinned node (on_split extension)
    assert sorted(eng.requests[r1].pinned) == sorted(n.id for n in pinned)
    # resume: unpinning releases both halves; nothing leaks
    eng.admission.push(r1)
    eng.run(32)
    assert len(eng.requests[r1].generated) == 4
    eng.release(r1)
    assert eng.pool.allocator.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}
