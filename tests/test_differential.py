"""Cross-backend differential harness (end-to-end engine runs).

Drives the decode engine for N steps over randomized prefix-forest
workloads (seeded; hypothesis widens the sweep when installed) with
every registered backend and asserts the generated token streams are
identical to the ``ref`` oracle — including runs that deliberately
undersize the KV pool so preemption, reclamation, and chunked prefill
all fire.  Every run also checks the allocator/forest are leak-free
after releasing all requests.
"""

import os

import jax
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS
from repro.configs import smoke_config
from repro.kernels import registry
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
PAGE = 8

# fixed workload whose pressure behaviour is pinned: 48-token doc shared
# by four requests; at 9 pages of 8 tokens the pool cannot hold the
# working set, so the engine must preempt-and-recompute (verified: the
# run reports >= 1 preemption and, with an 8-token prefill chunk,
# chunked prefill).
DOC = list(range(10, 10 + 48))
FIXED_PROMPTS = [DOC + [100 + 3 * i + j for j in range(3)]
                 for i in range(4)]
FIXED_MAX_NEW = 6
PRESSURE = dict(num_pages=9, prefill_chunk=8)


def make_workload(seed):
    """Seeded random doc-QA workload: (prompt, max_new, arrival_step)."""
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, CFG.vocab_size,
                         int(rng.integers(2, 7)) * PAGE).tolist()
            for _ in range(int(rng.integers(1, 3)))]
    out = []
    for _ in range(int(rng.integers(3, 6))):
        doc = docs[int(rng.integers(0, len(docs)))]
        tail = rng.integers(0, CFG.vocab_size,
                            int(rng.integers(1, 5))).tolist()
        out.append((doc + tail, int(rng.integers(3, 7)),
                    int(rng.integers(0, 3))))
    return out


def make_burst_workload(seed):
    """Seeded burst: requests sharing uncached prefixes, all arriving at
    step 0.  A decoy head request (its own private doc) absorbs the
    initial chunked-prefill budget, so the shared doc is still *uncached*
    when the burst's head is admitted and cascade co-admission pulls its
    partners out of the queue — the cascade path then computes the
    shared span once and batches the suffix chunks.
    """
    rng = np.random.default_rng(seed)
    decoy = rng.integers(0, CFG.vocab_size, 3 * PAGE).tolist()
    docs = [rng.integers(0, CFG.vocab_size,
                         int(rng.integers(3, 7)) * PAGE).tolist()
            for _ in range(int(rng.integers(1, 3)))]
    out = [(decoy + rng.integers(0, CFG.vocab_size, 2).tolist(), 4, 0)]
    for _ in range(int(rng.integers(3, 6))):
        doc = docs[int(rng.integers(0, len(docs)))]
        tail = rng.integers(0, CFG.vocab_size,
                            int(rng.integers(1, 5))).tolist()
        out.append((doc + tail, int(rng.integers(3, 7)), 0))
    return out


def run_workload(backend, workload, *, num_pages=512, prefill_chunk=None,
                 reserve_pages=0, max_steps=64, fused=False,
                 cascade=False, cache=False):
    """Run a workload end-to-end; returns ({idx: generated}, stats)."""
    from repro.serving.cache import CachePolicy
    eng = DecodeEngine(CFG, PARAMS, page_size=PAGE, num_pages=num_pages,
                       backend=backend, max_q=8, temperature=0.0,
                       prefill_chunk=prefill_chunk,
                       reserve_pages=reserve_pages, fused=fused,
                       cascade=cascade,
                       cache=CachePolicy() if cache else None)
    arrivals = {}
    for i, (_, _, arr) in enumerate(workload):
        arrivals.setdefault(arr, []).append(i)
    rid_of = {}
    for s in range(max_steps):
        for i in arrivals.pop(s, []):
            prompt, max_new, _ = workload[i]
            rid_of[i] = eng.add_request(prompt, max_new=max_new)
        if not arrivals and not eng.has_work():
            break
        eng.step()
    assert not arrivals and not eng.has_work(), "workload did not finish"
    outs = {i: list(eng.requests[rid_of[i]].generated)
            for i in range(len(workload))}
    for i, (_, max_new, _) in enumerate(workload):
        assert len(outs[i]) == max_new, (i, outs[i])
    stats = dict(eng.stats)
    stats["peak_pages"] = eng.pool.allocator.peak_used
    # no leaked pages / dangling refcounts / stray nodes after release
    for r in list(eng.requests):
        eng.release(r)
    if cache:
        eng._evict_cached(eng.pool.num_pages)   # drain cached residency
    assert eng.pool.num_free == eng.pool.num_pages, "leaked pages"
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}, "leaked forest nodes"
    return outs, stats


_ORACLE = {}


def oracle(key, workload, backend="ref", **kw):
    """Reference run (default: unconstrained ``ref``), cached per key.

    Cascade tests pass the same backend/chunking as the run under test
    with ``cascade=False`` — the oracle is then literally "sequential
    prefill, everything else equal"."""
    if key not in _ORACLE:
        _ORACLE[key] = run_workload(backend, workload, **kw)[0]
    return _ORACLE[key]


FIXED_WORKLOAD = [(p, FIXED_MAX_NEW, 0) for p in FIXED_PROMPTS]
SEEDS = [0, 1]


# --------------------------------------------------------------------- #
# every registered backend vs the ref oracle
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", registry.names())
@pytest.mark.parametrize("seed", SEEDS)
def test_differential_vs_ref(backend, seed):
    wl = make_workload(seed)
    got, _ = run_workload(backend, wl)
    assert got == oracle(("seed", seed), wl), backend


# --------------------------------------------------------------------- #
# memory pressure: eviction + chunked prefill, identical streams
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", registry.names())
def test_differential_under_pressure(backend):
    """Undersized pool + chunked prefill: every backend must still match
    the unconstrained oracle byte-for-byte."""
    got, stats = run_workload(backend, FIXED_WORKLOAD, **PRESSURE)
    assert got == oracle(("fixed",), FIXED_WORKLOAD), backend
    # the run really went through the pressure paths
    assert stats["preempted"] >= 1, stats
    assert stats["prefill_chunks"] >= 1, stats
    assert stats["recompute_tokens"] >= 1, stats


# --------------------------------------------------------------------- #
# fused single-dispatch decode: every backend, including pressure runs
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", registry.names())
def test_differential_fused_vs_ref(backend):
    """The fused (single-dispatch, async) decode path must reproduce the
    eager ``ref`` oracle byte-for-byte."""
    wl = make_workload(0)
    got, _ = run_workload(backend, wl, fused=True)
    assert got == oracle(("seed", 0), wl), backend


@pytest.mark.parametrize("backend", registry.names())
def test_differential_fused_under_pressure(backend):
    """Fused path through eviction + chunked prefill: streams identical
    to the unconstrained eager oracle."""
    got, stats = run_workload(backend, FIXED_WORKLOAD, fused=True,
                              **PRESSURE)
    assert got == oracle(("fixed",), FIXED_WORKLOAD), backend
    assert stats["preempted"] >= 1, stats
    assert stats["prefill_chunks"] >= 1, stats


def test_pressure_workload_completes_where_it_previously_oomed():
    """Acceptance: this workload exhausts the pool (peak == capacity —
    the seed engine raised MemoryError on the first failed alloc); now it
    completes every request via preemption/recompute with outputs
    identical to an unconstrained run."""
    got, stats = run_workload("codec-xla", FIXED_WORKLOAD, **PRESSURE)
    assert stats["peak_pages"] == PRESSURE["num_pages"]
    assert stats["preempted"] >= 1
    assert got == run_workload("codec-xla", FIXED_WORKLOAD)[0]


def test_oversized_prompt_still_fails_fast():
    eng = DecodeEngine(CFG, PARAMS, page_size=PAGE, num_pages=4,
                       backend="codec-xla", temperature=0.0)
    with pytest.raises(MemoryError):
        eng.add_request(list(range(200)), max_new=2)


# --------------------------------------------------------------------- #
# cascade prefill (DESIGN.md §14): cascade=True must be a pure
# performance mode — token streams byte-identical to sequential prefill
# across eager / fused / cached engine modes, leak-free after release
# --------------------------------------------------------------------- #
BURST_SEEDS = [0, 1, 2]


@pytest.mark.parametrize("seed", BURST_SEEDS)
def test_cascade_differential_eager(seed):
    wl = make_burst_workload(seed)
    got, stats = run_workload("codec-xla", wl, prefill_chunk=PAGE,
                              cascade=True)
    assert got == oracle(("burst", seed, "chunk"), wl,
                         backend="codec-xla", prefill_chunk=PAGE)
    # the burst really cascaded: groups formed and shared spans were
    # computed once on behalf of the whole group
    assert stats["cascade_groups"] >= 1, stats
    assert stats["cascade_shared_tokens"] > 0, stats


@pytest.mark.parametrize("seed", BURST_SEEDS[:2])
def test_cascade_differential_fused(seed):
    wl = make_burst_workload(seed)
    got, stats = run_workload("codec-xla", wl, prefill_chunk=PAGE,
                              cascade=True, fused=True)
    assert got == oracle(("burst", seed, "chunk"), wl,
                         backend="codec-xla", prefill_chunk=PAGE)
    assert stats["cascade_groups"] >= 1, stats


@pytest.mark.parametrize("seed", BURST_SEEDS[:2])
def test_cascade_differential_cached(seed):
    wl = make_burst_workload(seed)
    got, stats = run_workload("codec-xla", wl, prefill_chunk=PAGE,
                              cascade=True, cache=True)
    assert got == oracle(("burst", seed, "chunk"), wl,
                         backend="codec-xla", prefill_chunk=PAGE)
    assert stats["cascade_groups"] >= 1, stats


def test_cascade_under_pressure():
    """Cascade + undersized pool: preemption can hit mid-cascade and the
    recompute must still match the unconstrained sequential oracle."""
    got, stats = run_workload("codec-xla", FIXED_WORKLOAD, cascade=True,
                              **PRESSURE)
    assert got == oracle(("fixed",), FIXED_WORKLOAD)
    assert stats["preempted"] >= 1, stats


def test_cascade_batches_suffixes_into_one_dispatch():
    """Unbudgeted burst over one uncached doc: the whole group co-admits
    behind the decoy and its suffix chunks ride one padded dispatch."""
    wl = make_burst_workload(0)
    got, stats = run_workload("codec-xla", wl, prefill_chunk=PAGE,
                              cascade=True)
    assert stats["cascade_batches"] >= 1, stats
    assert stats["cascade_suffix_tokens"] >= 2, stats


# --------------------------------------------------------------------- #
# randomized sweep (hypothesis when installed; nightly widens via env)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=int(os.environ.get("DIFF_FUZZ_EXAMPLES", "4")),
              deadline=None, derandomize=True)
    @given(st.integers(2, 10_000))
    def test_differential_fuzz_constrained(seed):
        """Random workloads under a tight pool: codec-xla vs oracle."""
        wl = make_workload(seed)
        # pool sized to the largest single request plus a little slack so
        # every workload is admissible yet usually pressured
        need = max(-(-len(p) // PAGE) + -(-mn // PAGE)
                   for p, mn, _ in wl)
        pages = need + 2
        got, _ = run_workload("codec-xla", wl, num_pages=pages,
                              prefill_chunk=PAGE)
        assert got == oracle(("seed", seed), wl)
else:
    @pytest.mark.parametrize("seed", [2, 3])
    def test_differential_fuzz_constrained(seed):
        wl = make_workload(seed)
        need = max(-(-len(p) // PAGE) + -(-mn // PAGE)
                   for p, mn, _ in wl)
        got, _ = run_workload("codec-xla", wl, num_pages=need + 2,
                              prefill_chunk=PAGE)
        assert got == oracle(("seed", seed), wl)
