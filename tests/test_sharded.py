"""Multi-device sharded decode serving (``src/repro/distributed/``).

In-process tests cover the host-side pieces on one device — the
sharded allocator's per-shard invariants and placement policy, the
mesh-aware plan partitioner, the ICI merge term, registry capability
flags, and the full SPMD engine on a ``1x1`` mesh (the whole
shard_map path minus collectives).

The acceptance sweep runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the pattern
``test_launch.py`` uses — conftest strips XLA_FLAGS from the main
process): the same seeded forest workload at ``1x1 / 2x1 / 1x2 / 2x2``
meshes must produce greedy AND temp>0 token streams identical to the
single-device eager reference, with a forced sequence split of the
long shared prefix, an eviction + chunked-prefill pressure run on the
``2x2`` mesh, zero leaked pages on every shard, and the fused compile
count bounded by bucket signatures across the resharding events.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core import tree as tree_mod
from repro.core.cost_model import CostModel, HardwareSpec
from repro.distributed.kv_pool import ShardedPageAllocator
from repro.distributed.mesh import decode_mesh, parse_mesh
from repro.kernels import registry

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------------- #
# ICI merge cost (HardwareSpec.ici_bw finally read)
# --------------------------------------------------------------------- #
def test_merge_cost_wired_to_ici_bw():
    cm = CostModel(8, 2, 64, page_size=8)
    assert cm.merge_cost(1, 16) == 0.0
    assert cm.merge_cost(4, 0) == 0.0
    # more splits -> more butterfly rounds -> more cost
    assert 0 < cm.merge_cost(2, 16) < cm.merge_cost(4, 16) \
        < cm.merge_cost(8, 16)
    # more queries -> more wire bytes
    assert cm.merge_cost(2, 16) < cm.merge_cost(2, 64)
    # a slower interconnect must cost more (ici_bw actually read)
    slow = CostModel(8, 2, 64, page_size=8,
                     hw=HardwareSpec(ici_bw=1e9))
    assert slow.merge_cost(2, 64) > cm.merge_cost(2, 64)


# --------------------------------------------------------------------- #
# sharded allocator: per-shard invariants + placement
# --------------------------------------------------------------------- #
def test_sharded_allocator_invariants():
    al = ShardedPageAllocator(2, 8)
    assert al.num_pages == 16 and al.num_free == 16
    rows = al.alloc(5, hint=7)
    assert len(rows) == 5 and al.num_free == 11
    # trash rows (local id == pages_per_shard) are never handed out
    assert all(al.local_of(g) < al.pages_per_shard for g in rows)
    al.retain(rows[:2])
    al.release(rows[:2])            # refcount 2 -> 1, still allocated
    assert al.num_used == 5
    al.release(rows)
    assert al.num_free == 16
    al.check()
    with pytest.raises(ValueError):
        al.release([rows[0]])       # double free
    with pytest.raises(ValueError):
        al.release([al.pages_per_shard])   # shard 0's trash row
    with pytest.raises(MemoryError):
        al.alloc(17)


def test_placement_sequence_splits_long_nodes():
    """A node's pages stay on one shard until the quota, then continue
    on the next shard — contiguous runs = the sequence split."""
    al = ShardedPageAllocator(2, 8, seq_split_pages=2)
    rows = al.alloc(6, hint=1)
    owners = [al.shard_of(g) for g in rows]
    # runs of exactly quota length, alternating shards
    assert owners == [owners[0], owners[0], 1 - owners[0], 1 - owners[0],
                      owners[0], owners[0]]
    # a second node starts on the freest shard but keeps its own runs
    rows2 = al.alloc(2, hint=2)
    assert len({al.shard_of(g) for g in rows2}) == 1
    al.check()


def test_placement_without_quota_spills_only_when_full():
    al = ShardedPageAllocator(2, 4)
    rows = al.alloc(6, hint=3)      # shard of 4 fills, then spills
    owners = [al.shard_of(g) for g in rows]
    assert owners[:4] == [owners[0]] * 4 and owners[4:] == [1 - owners[0]] * 2
    al.check()


# --------------------------------------------------------------------- #
# mesh-aware plan partitioner
# --------------------------------------------------------------------- #
def _forest_with_sharded_pages(num_shards=2, quota=2):
    ps = 8
    forest = tree_mod.PrefixForest(ps)
    doc = np.arange(100, 100 + 6 * ps, dtype=np.int32)   # 6-page shared node
    for r in range(3):
        forest.insert_tokens(r, np.concatenate(
            [doc, np.asarray([200 + r, 201 + r], np.int32)]))
    al = ShardedPageAllocator(num_shards, 32, seq_split_pages=quota)
    for node in forest.real_nodes():
        npages = -(-node.length // ps)
        node.page_ids = al.alloc(npages, hint=node.id)
    return forest, al


def test_build_sharded_plan_partitions_and_localizes():
    forest, al = _forest_with_sharded_pages()
    cm = CostModel(4, 2, 16, page_size=8)
    sp = plan_mod.build_sharded_plan(forest, cm, al.num_shards, al.stride,
                                     num_lanes=2, max_q=8)
    assert len(sp.shards) == 2
    assert sp.seq_splits >= 1                    # the 6-page doc node split
    assert sp.merge_cost > 0 and sp.makespan > max(
        p.makespan for p in sp.shards) - 1e-12
    # common bucketed shapes across shards (stackable)
    shapes = {(p.max_steps, p.task_qnum.shape[0], p.max_pages,
               p.num_queries) for p in sp.shards}
    assert len(shapes) == 1
    # every page id is shard-local (within the shard's block incl. trash)
    for p in sp.shards:
        assert p.step_page.max() < al.stride
        assert p.task_pages.max() < al.stride
    # coverage: per-shard valid KV tokens sum to the plan-covered total
    covered = sum(int(p.task_kvlen[t])
                  for p in sp.shards for t in range(p.num_tasks))
    total = sum(n.length for n in forest.real_nodes())
    assert covered == total
    st = sp.stats()
    assert st["num_shards"] == 2 and st["seq_splits"] == sp.seq_splits
    assert st["merge_cost"] > 0


def test_sharded_plan_single_shard_has_no_merge_term():
    forest, al = _forest_with_sharded_pages(num_shards=1, quota=0)
    cm = CostModel(4, 2, 16, page_size=8)
    sp = plan_mod.build_sharded_plan(forest, cm, 1, al.stride)
    assert sp.merge_cost == 0.0 and sp.seq_splits == 0
    assert sp.makespan == pytest.approx(sp.shards[0].makespan)


# --------------------------------------------------------------------- #
# registry capability flag + engine guards
# --------------------------------------------------------------------- #
def test_registry_shardable_flags():
    assert registry.get("codec-xla").shardable
    assert registry.get("codec-pallas").shardable
    assert not registry.get("ref").shardable
    assert not registry.get("hydragen").shardable
    assert set(registry.names(shardable=True)) == {"codec-pallas",
                                                   "codec-xla"}
    for n in registry.names(shardable=True):
        assert registry.get(n).jit_safe     # shardable implies jit-safe


def test_parse_mesh():
    assert parse_mesh("2x2") == (2, 2)
    assert parse_mesh("1X4") == (1, 4)
    with pytest.raises(ValueError):
        parse_mesh("2")
    with pytest.raises(ValueError):
        parse_mesh("0x2")


def test_decode_mesh_rejects_non_pow2_data():
    with pytest.raises(ValueError):
        decode_mesh(3, 1)


def test_mesh_engine_guards():
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    mesh = decode_mesh(1, 1)
    with pytest.raises(ValueError, match="fused"):
        DecodeEngine(cfg, params, mesh=mesh)
    with pytest.raises(ValueError, match="shardable"):
        DecodeEngine(cfg, params, mesh=mesh, fused=True, backend="hydragen")


# --------------------------------------------------------------------- #
# 1x1 mesh: the whole SPMD path on one device, byte-identical streams
# --------------------------------------------------------------------- #
def test_mesh_1x1_engine_matches_plain_engine():
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 42))
    prompts = [doc + [100 + i] for i in range(3)]

    def run(**kw):
        eng = DecodeEngine(cfg, params, page_size=8, num_pages=64,
                           backend="codec-xla", max_q=8, temperature=0.0,
                           **kw)
        rids = [eng.add_request(p, max_new=4) for p in prompts]
        eng.run(16)
        outs = {i: list(eng.requests[r].generated)
                for i, r in enumerate(rids)}
        return outs, eng

    ref, _ = run(fused=False)
    got, eng = run(fused=True, mesh=decode_mesh(1, 1))
    assert got == ref
    assert eng.stats["fused_calls"] == eng.stats["steps"]
    assert eng.fused_cache_size <= len(eng.bucket_signatures)
    # leak-free per shard after release
    for r in list(eng.requests):
        eng.release(r)
    for s in eng.pool.allocator.shards:
        assert s.num_free == s.num_pages
    eng.pool.allocator.check()


# --------------------------------------------------------------------- #
# acceptance sweep: 4 forced host devices, all mesh shapes, pressure
# --------------------------------------------------------------------- #
SHARDED_PARITY = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    from repro.distributed import decode_mesh

    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    DOC = list(range(10, 58))                      # 6-page shared prefix
    PROMPTS = [DOC + [100 + 3 * i + j for j in range(3)] for i in range(4)]
    LATE = DOC + [250, 251]                        # arrives mid-decode

    def run(mesh=None, temperature=0.0, num_pages=256, prefill_chunk=None,
            fused=True, check_leaks=True, replicate=False):
        eng = DecodeEngine(cfg, params, page_size=8, num_pages=num_pages,
                           backend="codec-xla", max_q=8,
                           temperature=temperature, mesh=mesh, fused=fused,
                           seq_split_pages=2 if mesh is not None else 0,
                           prefill_chunk=prefill_chunk, replicate=replicate)
        rids = [eng.add_request(p, max_new=6) for p in PROMPTS]
        eng.step(); eng.step()
        rids.append(eng.add_request(LATE, max_new=4))
        eng.run(96)
        outs = {i: list(eng.requests[r].generated)
                for i, r in enumerate(rids)}
        assert all(len(outs[i]) == eng.requests[r].max_new
                   for i, r in enumerate(rids)), "unfinished requests"
        stats = dict(eng.stats)
        stats["seq_splits"] = sum(sp.seq_splits
                                  for sp in eng._sharded_plans.values())
        stats["compile_ok"] = (eng.fused_cache_size
                               <= len(eng.bucket_signatures)) if eng.fused \\
            else True
        for r in list(eng.requests):
            eng.release(r)
        if check_leaks and mesh is not None:
            for s in eng.pool.allocator.shards:
                assert s.num_free == s.num_pages, "leaked pages on a shard"
        eng.pool.allocator.check()
        return outs, stats

    ref, _ = run(mesh=None, fused=False)           # single-device eager
    reft, _ = run(mesh=None, fused=False, temperature=0.7)
    for d, m in ((1, 1), (2, 1), (1, 2), (2, 2)):
        mesh = decode_mesh(d, m)
        got, st = run(mesh=mesh)
        assert got == ref, f"greedy stream diverged on {d}x{m}"
        assert st["compile_ok"], f"compile count unbounded on {d}x{m}"
        if d > 1:
            assert st["seq_splits"] >= 1, f"no sequence split on {d}x{m}"
        gott, _ = run(mesh=mesh, temperature=0.7)
        assert gott == reft, f"temp>0 stream diverged on {d}x{m}"
        print(f"mesh {d}x{m}: parity OK")

    # forced replication: the hot shared prefix is promoted to replicas
    # on every data shard, streams stay byte-identical (replicated rows
    # are computed identically everywhere and skip the wire), and every
    # replica page is reclaimed on release
    for d, m in ((2, 1), (2, 2)):
        gotr, str_ = run(mesh=decode_mesh(d, m), replicate=True)
        assert gotr == ref, f"replicated stream diverged on {d}x{m}"
        assert str_["replica_promotions"] >= 1, str_
        assert str_["compile_ok"], str_
        gotrt, _ = run(mesh=decode_mesh(d, m), replicate=True,
                       temperature=0.7)
        assert gotrt == reft, f"replicated temp stream diverged on {d}x{m}"
        print(f"mesh {d}x{m}: replication OK")

    # 2x2 under memory pressure: eviction + chunked prefill, same stream
    gotp, stp = run(mesh=decode_mesh(2, 2), num_pages=10, prefill_chunk=8)
    assert gotp == ref, "pressured 2x2 stream diverged"
    assert stp["preempted"] >= 1, stp
    assert stp["prefill_chunks"] >= 1, stp
    assert stp["compile_ok"], stp

    # replication enabled under the same pressure: the free-page guard
    # and the demotion reclaim tier must keep the stream correct
    gotrp, strp = run(mesh=decode_mesh(2, 1), num_pages=12,
                      prefill_chunk=8, replicate=True)
    assert gotrp == ref, "pressured replicated stream diverged"
    assert strp["compile_ok"], strp
    print("SHARDED_PARITY_OK")
""")


def test_sharded_parity_subprocess(tmp_path):
    """Acceptance: mesh-shape invariance + pressure on 4 fake devices."""
    script = tmp_path / "sharded_parity.py"
    script.write_text(SHARDED_PARITY)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert "SHARDED_PARITY_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


ARCH_SWEEP = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    from repro.distributed import decode_mesh

    for arch, page in (("gemma3-1b", 16),        # sliding-window layers
                       ("jamba-v0.1-52b", 8),    # hybrid attn + mamba
                       ("mamba2-2.7b", 8)):      # attention-free
        cfg = smoke_config(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        doc = list(range(10, 10 + 64))
        prompts = [doc + [100 + i, 101 + i] for i in range(2)]
        outs = {}
        for mode in ("eager", "mesh"):
            kw = (dict(fused=True, mesh=decode_mesh(2, 1),
                       seq_split_pages=2) if mode == "mesh"
                  else dict(fused=False))
            eng = DecodeEngine(cfg, params, page_size=page, num_pages=64,
                               backend="codec-xla", max_q=8,
                               temperature=0.0, **kw)
            for p in prompts:
                eng.add_request(p, max_new=4)
            outs[mode] = eng.run(12)
            eng.pool.allocator.check()
        assert outs["eager"] == outs["mesh"], (arch, outs)
        print(arch, "OK")
    print("ARCH_SWEEP_OK")
""")


def test_sharded_arch_sweep_subprocess(tmp_path):
    """Sliding-window, hybrid-SSM, and attention-free archs through the
    2-device sharded step (per-window plans, replicated Mamba state)."""
    script = tmp_path / "sharded_archs.py"
    script.write_text(ARCH_SWEEP)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert "ARCH_SWEEP_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


POR_PROPERTY = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import itertools
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.kernels import por
    from repro.kernels.ref import MASK_VALUE

    rng = np.random.default_rng(0)

    def partials(D, rows, h, d, contrib):
        # per-shard stacked partials; non-contributing shards hold the
        # POR identity (o=0, m=MASK_VALUE, l=0) exactly as the sharded
        # step's tail/plan paths produce it
        o = rng.standard_normal((D, rows, h, d)).astype(np.float32)
        m = (3.0 * rng.standard_normal((D, rows, h))).astype(np.float32)
        l = rng.uniform(0.5, 4.0, (D, rows, h)).astype(np.float32)
        for s in range(D):
            if not contrib[s]:
                o[s], m[s], l[s] = 0.0, MASK_VALUE, 0.0
        return (jnp.asarray(o), jnp.asarray(m), jnp.asarray(l))

    def build(D):
        devs = np.asarray(jax.devices()[:D]).reshape(D)
        mesh = Mesh(devs, ("data",))
        spec = (P("data"),) * 3
        def sub(o, m, l, c):
            ro, rm, rl = por.por_subgroup_merge(o[0], m[0], l[0],
                                                "data", D, c)
            return ro[None], rm[None], rl[None]
        def full(o, m, l):
            ro, rm, rl = por.por_allmerge(o[0], m[0], l[0], "data", D)
            return ro[None], rm[None], rl[None]
        # contrib is a TRACED argument, exactly as the engine passes it:
        # ONE compiled program serves every ownership mask
        f_sub = jax.jit(shard_map(sub, mesh=mesh, in_specs=spec + (P(),),
                                  out_specs=spec, check_rep=False))
        f_full = jax.jit(shard_map(full, mesh=mesh, in_specs=spec,
                                   out_specs=spec, check_rep=False))
        return f_sub, f_full

    for D in (1, 2, 4):
        masks = [m for m in itertools.product([False, True], repeat=D)
                 if any(m)]
        f_sub, f_full = build(D)
        for trial in range(3):
            for mask in masks:
                args = partials(D, rows=5, h=2, d=16, contrib=mask)
                c = jnp.asarray(np.asarray(mask))
                got = [np.asarray(a) for a in f_sub(*args, c)]
                want = [np.asarray(a) for a in f_full(*args)]
                # the max-space statistic matches the full butterfly
                # BITWISE for every mask (identity merges are exact and
                # max admits no fused-multiply reassociation)...
                np.testing.assert_array_equal(got[1], want[1])
                # ...o and l match within FMA slot asymmetry + the one
                # (o*l)/l rounding only the butterfly's identity merges
                # pay; likewise across devices
                for g, w in zip(got, want):
                    np.testing.assert_allclose(g, w, rtol=2e-6, atol=2e-6)
                    for s in range(1, D):
                        np.testing.assert_allclose(g[s], g[0], rtol=2e-6,
                                                   atol=2e-6)
                ids = [i for i, f in enumerate(mask) if f]
                if len(ids) == 1:
                    # single contributor: pure copy cascade — the
                    # owner's partials reach every shard UNPERTURBED,
                    # bitwise (the wire-skip float-hygiene guarantee;
                    # the full butterfly would perturb o)
                    src = [np.asarray(a)[ids[0]] for a in args]
                    for g, s_ in zip(got, src):
                        for s in range(D):
                            np.testing.assert_array_equal(g[s], s_)
        # one compile each: the mask never enters the jit signature
        assert f_sub._cache_size() == 1, D
        # packed transfer: ONE ppermute per round (the full butterfly
        # pays three; copy rounds still ship the packed buffer so the
        # program stays shape-uniform)
        args = partials(D, rows=5, h=2, d=16, contrib=masks[0])
        rounds = max(D - 1, 0).bit_length()
        c = jnp.asarray(np.asarray(masks[0]))
        txt_sub = str(jax.make_jaxpr(f_sub)(*args, c))
        txt_full = str(jax.make_jaxpr(f_full)(*args))
        assert txt_sub.count("ppermute") == rounds, D
        assert txt_full.count("ppermute") == 3 * rounds, D
        print(f"D={D}: {len(masks)} masks OK")
    print("POR_PROPERTY_OK")
""")


def test_por_subgroup_merge_property_subprocess(tmp_path):
    """Property: for EVERY ownership mask at axis sizes 1/2/4, the
    sparse subgroup merge matches the full POR butterfly — bitwise in
    max space, to FMA slot asymmetry in o/l, and bitwise-verbatim for
    single-contributor rows — with one packed ppermute per round vs the
    butterfly's three and a single compiled program per axis size."""
    script = tmp_path / "por_property.py"
    script.write_text(POR_PROPERTY)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert "POR_PROPERTY_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]
