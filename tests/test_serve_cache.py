"""Persistent cross-request prefix cache + streaming (DESIGN.md §11).

Engine-lifetime persistence: completed requests detach but their prefix
nodes stay resident, so a later wave over the same document skips its
prefill; LRU/TTL policy bounds residency; cached nodes are the first
reclaim tier under pressure; token streams stay byte-identical to a
cold engine.
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import tree as tree_mod
from repro.models import transformer as T
from repro.serving.cache import CachePolicy, PrefixCache
from repro.serving.engine import DecodeEngine

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
PAGE = 16
DOC = list(range(100, 148))          # 48 tokens = 3 pages shared prefix


def _engine(**kw):
    defaults = dict(page_size=PAGE, num_pages=128, backend="codec-xla",
                    max_q=8, temperature=0.0)
    defaults.update(kw)
    return DecodeEngine(CFG, PARAMS, **defaults)


def _wave(i, n=3):
    """n prompts sharing DOC, with wave- and request-unique tails."""
    return [DOC + [200 + 10 * i + k, 250 + k] for k in range(n)]


# --------------------------------------------------------------------- #
# cache policy unit tests (LRU order, TTL expiry)
# --------------------------------------------------------------------- #
def test_lru_order_and_ttl_unit():
    f = tree_mod.PrefixForest(4)
    f.insert_tokens(0, np.arange(8, dtype=np.int32))
    f.insert_tokens(1, np.asarray([90, 91, 92, 93], np.int32))
    cache = PrefixCache(f, CachePolicy(ttl_steps=2, max_pages=1))
    na = f.nodes[f.leaf_of[0]]
    nb = f.nodes[f.leaf_of[1]]
    na.page_ids = [0, 1]
    nb.page_ids = [2]
    cache.stamp(na)                  # touched at clock 0
    cache.tick(); cache.tick()
    cache.stamp(nb)                  # touched at clock 2
    f.detach_request(0)
    f.detach_request(1)
    # LRU: least recently touched first
    assert [n.id for n in cache.candidates()] == [na.id, nb.id]
    assert cache.resident_pages() == 3
    assert cache.over_cap() == 2
    # TTL at clock 3: A aged out (3 > 2), B not (1)
    cache.tick()
    assert [n.id for n in cache.expired()] == [na.id]
    # a fresh touch rescues A from both expiry and LRU headship
    cache.stamp(na)
    assert not cache.expired()
    assert [n.id for n in cache.candidates()] == [nb.id, na.id]


def test_retainable_excludes_drafts_and_empty_leaves():
    f = tree_mod.PrefixForest(4)
    f.insert_tokens(0, np.arange(8, dtype=np.int32))
    cache = PrefixCache(f)
    node = f.nodes[f.leaf_of[0]]
    node.page_ids = [0, 1]
    assert cache.retainable(node)
    d = f.add_draft(node.id, 42)
    d.page_ids = [2]
    assert not cache.retainable(d)           # unverified draft tokens
    empty = f.add_node(node.id, 0, np.zeros(0, np.int32))
    assert not cache.retainable(empty)       # nothing worth keeping


# --------------------------------------------------------------------- #
# engine-lifetime persistence
# --------------------------------------------------------------------- #
def test_two_waves_hit_cached_system_prompt():
    streams = {}

    def cb(rid, tok):
        streams.setdefault(rid, []).append(tok)

    eng = _engine(cache=True)
    for p in _wave(0):
        eng.add_request(p, max_new=4, on_token=cb)
    eng.run(32)
    prefill_w1 = eng.stats["prefill_tokens"]
    hits_w1 = eng.cache.stats["hits"]
    assert eng.cache.resident_pages() > 0         # doc stayed resident
    # wave 2 through the SAME engine hits wave 1's cached document
    w2 = _wave(1)
    assert eng.forest.match_len(np.asarray(w2[0], np.int32)) >= len(DOC)
    for p in w2:
        eng.add_request(p, max_new=4, on_token=cb)
    eng.run(32)
    assert eng.cache.stats["hits"] > hits_w1      # hit-rate incremented
    assert eng.cache.hit_rate > 0
    assert any(s.get("cache_hits", 0) > 0 for s in eng.step_stats)
    assert eng.step_stats[-1]["cache_resident_bytes"] > 0
    # wave 2 prefilled only the private tails, never the 48-token doc
    assert (eng.stats["prefill_tokens"] - prefill_w1
            == sum(len(p) - len(DOC) for p in w2))
    # token streams byte-identical to a cold (cache-less) engine
    cold = _engine()
    for p in _wave(0) + _wave(1):
        cold.add_request(p, max_new=4)
    cold_out = cold.run(32)
    warm = {r: q.generated for r, q in eng.requests.items()}
    assert warm == cold_out
    assert streams == warm                        # callbacks saw it all


def test_release_after_detach_keeps_cache():
    eng = _engine(cache=True)
    r = eng.add_request(DOC + [1, 2], max_new=2)
    eng.run(8)
    eng.release(r)                   # the request goes, its prefix stays
    assert r not in eng.requests
    assert eng.forest.match_len(np.asarray(DOC, np.int32)) == len(DOC)
    eng.pool.allocator.check()
    eng.forest.validate()


# --------------------------------------------------------------------- #
# eviction: TTL sweep, LRU cap, pressure tier
# --------------------------------------------------------------------- #
def test_ttl_eviction_empties_cache():
    eng = _engine(cache=CachePolicy(ttl_steps=3))
    for p in _wave(0, n=2):
        eng.add_request(p, max_new=3)
    eng.run(32)
    assert eng.cache.resident_pages() > 0
    for _ in range(12):              # idle: the clock ticks past the TTL
        eng.step()
    assert eng.cache.stats["evicted_nodes"] > 0
    assert eng.cache.resident_pages() == 0
    assert eng.pool.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    eng.forest.validate()


def test_max_pages_cap_evicts_lru_first():
    doc_a = list(range(100, 164))    # each doc: 4 pages (+1 tail page)
    doc_b = list(range(0, 64))       # disjoint from doc_a, in-vocab
    eng = _engine(cache=CachePolicy(max_pages=5), num_pages=256)
    eng.add_request(doc_a + [1, 2], max_new=2)
    eng.run(16)
    eng.add_request(doc_b + [3, 4], max_new=2)
    eng.run(16)
    # the cap forced the LRU doc (A) out; B stays resident
    assert eng.cache.resident_pages() <= 5
    assert eng.cache.stats["evicted_pages"] > 0
    assert eng.forest.match_len(np.asarray(doc_b, np.int32)) == 64
    assert eng.forest.match_len(np.asarray(doc_a, np.int32)) < 64
    eng.pool.allocator.check()


def test_pressure_reclaims_cache_before_preempting():
    doc_a = list(range(100, 164))    # 64 tokens -> 5 cached pages
    eng = _engine(cache=True, num_pages=12)
    eng.add_request(doc_a + [1, 2], max_new=2)
    eng.run(16)
    assert eng.cache.resident_pages() > 0
    # two fresh disjoint requests outgrow the free list: the cached doc
    # is the FIRST reclaim tier, so no live request gets preempted
    r1 = eng.add_request(list(range(0, 48)), max_new=4)
    r2 = eng.add_request(list(range(192, 240)), max_new=4)
    eng.run(32)
    assert len(eng.requests[r1].generated) == 4
    assert len(eng.requests[r2].generated) == 4
    assert eng.cache.stats["evicted_pages"] > 0
    assert eng.stats["preempted"] == 0
    eng.pool.allocator.check()


# --------------------------------------------------------------------- #
# streaming callbacks
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fused", [False, True])
def test_streaming_callbacks_in_order(fused):
    got = {}

    def cb(rid, tok):
        got.setdefault(rid, []).append(tok)

    eng = _engine(fused=fused, cache=True)
    rids = [eng.add_request(p, max_new=5, on_token=cb)
            for p in _wave(0, n=2)]
    eng.run(32)
    for r in rids:
        assert got[r] == eng.requests[r].generated
        assert len(got[r]) == 5
        assert all(t >= 0 for t in got[r])   # placeholders never leak
