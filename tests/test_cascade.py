"""Cascade prefill unit tests (DESIGN.md §14).

Each test pins one hazard of sharing prefix compute across concurrent
prefills: chunk boundaries landing mid-shared-node, node splits firing
while a cascade is mid-flight, one member stalling on pages while its
siblings proceed, preemption of a member mid-cascade, and hybrid /
recurrent architectures resuming from the cascaded ``meta["ssm"]``
boundary states.  The invariant throughout: ``cascade=True`` is a pure
performance mode — greedy token streams must be byte-identical to the
same engine with sequential prefill.

Also locks down two accounting fixes that rode along with the cascade
work: the fully-cached-prompt branch recomputes exactly one token for
the final logits, and ``prefill_stalls`` counts stalled *chunks*, not
once per request.
"""

import jax
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine

PAGE = 8
CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

# shared doc (3 pages) + a decoy head whose private prompt absorbs the
# first chunk budgets so the doc is still uncached when the burst's head
# admits and pulls its cascade partners out of the queue
DOC = list(range(10, 10 + 3 * PAGE))
DECOY = list(range(120, 120 + 3 * PAGE)) + [99, 98]


def _engine(cfg=CFG, params=PARAMS, **kw):
    defaults = dict(page_size=PAGE, num_pages=256, backend="codec-xla",
                    max_q=8, temperature=0.0)
    defaults.update(kw)
    return DecodeEngine(cfg, params, **defaults)


def _drive(eng, schedule, max_steps=96, release=True):
    """Run a ``(arrival_step, prompt, max_new)`` schedule to completion.

    Returns ``{schedule index: generated tokens}`` and (when ``release``)
    checks the engine is leak-free after all requests are released.
    """
    arrivals = {}
    for i, (arr, _, _) in enumerate(schedule):
        arrivals.setdefault(arr, []).append(i)
    rid_of = {}
    for s in range(max_steps):
        for i in arrivals.pop(s, []):
            _, prompt, max_new = schedule[i]
            rid_of[i] = eng.add_request(prompt, max_new=max_new)
        if not arrivals and not eng.has_work():
            break
        eng.step()
    assert not arrivals and not eng.has_work(), "schedule did not finish"
    outs = {i: list(eng.requests[r].generated) for i, r in rid_of.items()}
    if release:
        for r in list(eng.requests):
            eng.release(r)
        assert eng.pool.num_free == eng.pool.num_pages, "leaked pages"
        eng.pool.allocator.check()
        assert set(eng.forest.nodes) == {0}, "leaked forest nodes"
    return outs


def _burst(doc=DOC, n=3, tail=2):
    """Decoy head + ``n`` requests sharing ``doc``, all arriving at 0."""
    sched = [(0, DECOY, 4)]
    sched += [(0, doc + [200 + 5 * i + j for j in range(tail)], 4)
              for i in range(n)]
    return sched


# --------------------------------------------------------------------- #
# chunk boundary mid-shared-node
# --------------------------------------------------------------------- #
def test_chunk_boundary_mid_shared_node():
    """prefill_chunk=4 < page_size=8: every shared-span chunk ends in the
    middle of a node, so siblings must resume from a mid-node boundary —
    streams still byte-identical to sequential prefill."""
    sched = _burst()
    seq = _drive(_engine(prefill_chunk=4), sched)
    eng = _engine(prefill_chunk=4, cascade=True)
    cas = _drive(eng, sched, release=False)
    assert cas == seq
    assert eng.stats["cascade_groups"] >= 1, eng.stats
    assert eng.stats["cascade_shared_tokens"] > 0, eng.stats


# --------------------------------------------------------------------- #
# on_split during a mid-flight cascade
# --------------------------------------------------------------------- #
def test_on_split_mid_cascade():
    """A request landing mid-prefill whose prompt diverges inside the
    shared doc splits the node the cascade is filling; pin bookkeeping
    (``on_split``) and the streams must both survive."""
    sched = _burst(doc=list(range(10, 10 + 4 * PAGE)))
    splitter = (2, list(range(10, 10 + 2 * PAGE)) + [210, 211], 4)
    sched.append(splitter)
    seq = _drive(_engine(prefill_chunk=PAGE), sched)

    eng = _engine(prefill_chunk=PAGE, cascade=True)
    fired = []
    orig = eng.forest.on_split

    def spy(upper, lower):
        fired.append((upper.id, lower.id))
        if orig is not None:
            orig(upper, lower)

    eng.forest.on_split = spy
    cas = _drive(eng, sched, release=False)
    assert fired, "expected a node split during the run"
    assert cas == seq
    eng.check()


# --------------------------------------------------------------------- #
# page stall for one member while siblings proceed
# --------------------------------------------------------------------- #
def test_page_stall_one_member():
    """One member's suffix chunks stall on pages for 3 chunks; its
    siblings keep cascading and every stream still matches sequential."""
    sched = _burst()
    seq = _drive(_engine(prefill_chunk=PAGE), sched)

    eng = _engine(prefill_chunk=PAGE, cascade=True)
    rids = [eng.add_request(p, max_new=mn) for _, p, mn in sched]
    victim = rids[-1]
    orig = eng._ensure_pages_upto
    denied = {"n": 0}

    def flaky(rid, upto):
        if rid == victim and denied["n"] < 3:
            denied["n"] += 1
            return False
        return orig(rid, upto)

    eng._ensure_pages_upto = flaky
    for _ in range(96):
        if not eng.has_work():
            break
        eng.step()
    assert not eng.has_work()
    cas = {i: list(eng.requests[r].generated) for i, r in enumerate(rids)}
    assert cas == seq
    assert denied["n"] == 3
    assert eng.stats["prefill_stalls"] >= 3, eng.stats
    assert eng.stats["cascade_shared_tokens"] > 0, eng.stats


# --------------------------------------------------------------------- #
# preemption of one member mid-cascade
# --------------------------------------------------------------------- #
def test_preempt_member_mid_cascade():
    """Undersized pool: a cascade member gets preempted mid-prefill and
    its recompute (through the cascade path again) must reproduce the
    unconstrained sequential streams byte-for-byte."""
    doc = list(range(10, 10 + 6 * PAGE))
    sched = [(0, doc + [200 + 3 * i + j for j in range(3)], 6)
             for i in range(4)]
    seq = _drive(_engine(), sched)
    eng = _engine(num_pages=9, prefill_chunk=PAGE, cascade=True)
    cas = _drive(eng, sched, release=False)
    assert cas == seq
    assert eng.stats["preempted"] >= 1, eng.stats
    assert eng.stats["recompute_tokens"] >= 1, eng.stats


# --------------------------------------------------------------------- #
# hybrid / recurrent archs resume from the cascaded meta["ssm"]
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "mamba2-2.7b"])
def test_recurrent_resume_from_cascaded_state(arch):
    """Mamba and hybrid models: siblings resume from the SSM boundary
    states the cascaded shared span cached (mid-node carry included,
    prefill_chunk=4 forces non-aligned boundaries)."""
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = [(10 + i) % cfg.vocab_size for i in range(24)]
    sched = [(0, [(150 + i) % cfg.vocab_size for i in range(16)] + [7, 8],
              3)]
    sched += [(0, doc + [100 + 3 * i + j for j in range(2)], 4)
              for i in range(3)]
    seq = _drive(_engine(cfg, params, prefill_chunk=4), sched)
    eng = _engine(cfg, params, prefill_chunk=4, cascade=True)
    cas = _drive(eng, sched, release=False)
    assert cas == seq
    assert eng.stats["cascade_groups"] >= 1, eng.stats
    assert eng.stats["cascade_shared_tokens"] > 0, eng.stats


# --------------------------------------------------------------------- #
# fully-cached prompt: minimal final-logit recompute (regression)
# --------------------------------------------------------------------- #
def test_fully_cached_prompt_recomputes_one_token():
    """A prompt whose KV is entirely cached needs exactly ONE recomputed
    token (the last, for the final logits) — the old code re-ran the
    whole last node."""
    eng = _engine(prefill_chunk=PAGE)
    prompt = list(range(10, 10 + 3 * PAGE))
    r0 = eng.add_request(prompt, max_new=4)
    first = eng.run(48)[r0]
    before = eng.stats["prefill_tokens"]
    r1 = eng.add_request(prompt, max_new=4)
    while eng.has_work():
        eng.step()
    assert eng.stats["prefill_tokens"] - before == 1, eng.stats
    assert list(eng.requests[r1].generated) == first


# --------------------------------------------------------------------- #
# prefill_stalls counts stalled chunks, not once per request
# --------------------------------------------------------------------- #
def test_prefill_stalls_counts_chunks():
    sched = [(0, list(range(10, 10 + 3 * PAGE)), 3)]
    seq = _drive(_engine(prefill_chunk=PAGE), sched)

    eng = _engine(prefill_chunk=PAGE)
    orig = eng._ensure_pages_upto
    denied = {"n": 0}

    def flaky(rid, upto):
        if denied["n"] < 3:
            denied["n"] += 1
            return False
        return orig(rid, upto)

    eng._ensure_pages_upto = flaky
    cas = _drive(eng, sched, release=False)
    assert cas == seq
    assert eng.stats["prefill_stalls"] == 3, eng.stats
