"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Pallas kernels run in interpret mode on CPU; shapes sweep GQA group
structure, page counts, dtypes, masks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, plan as plan_mod, tree as tree_mod
from repro.kernels import flash_decode, ops, pac as pac_mod, por, ref

from conftest import dense_from_pool, make_pool


# --------------------------------------------------------------------- #
# PAC oracle self-consistency + the Pallas kernel
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pac_ref_matches_dense_softmax(hq, hkv, dtype):
    nq, n, d = 3, 37, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (nq, hq, d), dtype)
    k = jax.random.normal(k2, (n, hkv, d), dtype)
    v = jax.random.normal(k3, (n, hkv, d), dtype)
    o, m, l = ref.pac_ref(q, k, v)
    # dense check per head
    g = hq // hkv
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    for h in range(hq):
        kv = h // g
        s = qf[:, h] @ kf[:, kv].T / np.sqrt(d)
        expect = jax.nn.softmax(s, -1) @ vf[:, kv]
        np.testing.assert_allclose(o[:, h], expect,
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("hq,hkv,d", [(4, 2, 16), (8, 1, 32), (6, 6, 8)])
@pytest.mark.parametrize("page", [16, 64])
def test_pac_kernel_vs_ref(hq, hkv, d, page):
    """The full PAC pallas kernel over a compiled plan == python oracle."""
    f = tree_mod.two_level(4, 3 * page, page + 3, block_size=page)
    cm = cost_model.CostModel(hq, hkv, d, page_size=page)
    k_pool, v_pool = make_pool(f, hkv, d)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8)
    q = jax.random.normal(jax.random.PRNGKey(3), (4, hq, d))
    o_pal = ops.codec_attention(q, k_pool, v_pool, p, impl="pallas")
    o_ref = ops.codec_attention(q, k_pool, v_pool, p, impl="ref")
    np.testing.assert_allclose(o_pal, o_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pac_kernel_dtypes(dtype):
    page, hq, hkv, d = 32, 4, 2, 16
    f = tree_mod.two_level(3, 2 * page, page, block_size=page)
    cm = cost_model.CostModel(hq, hkv, d, page_size=page)
    k_pool, v_pool = make_pool(f, hkv, d, dtype=dtype)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4)
    q = jax.random.normal(jax.random.PRNGKey(5), (3, hq, d), dtype)
    o_pal = ops.codec_attention(q, k_pool, v_pool, p, impl="pallas")
    o_ref = ops.codec_attention(q, k_pool, v_pool, p, impl="ref")
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_pac_kernel_sliding_window():
    page, hq, hkv, d, win = 16, 4, 2, 16, 24
    f = tree_mod.two_level(3, 4 * page, 2 * page, block_size=page)
    cm = cost_model.CostModel(hq, hkv, d, page_size=page)
    k_pool, v_pool = make_pool(f, hkv, d)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4, window=win)
    q = jax.random.normal(jax.random.PRNGKey(7), (3, hq, d))
    o_pal = ops.codec_attention(q, k_pool, v_pool, p, impl="pallas",
                                window=win)
    o_xla = ops.codec_attention(q, k_pool, v_pool, p, impl="xla",
                                window=win)
    # dense windowed oracle
    kd, vd, lens = dense_from_pool(f, k_pool, v_pool)
    o_dense = ref.decode_attention_ref(q, jnp.asarray(kd), jnp.asarray(vd),
                                       jnp.asarray(lens), window=win)
    np.testing.assert_allclose(o_pal, o_dense, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(o_xla, o_dense, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# POR kernel
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(1, 4, 16), (5, 8, 32)])
def test_por_kernel_vs_ref(shape):
    nq, h, d = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    o1 = jax.random.normal(ks[0], (nq, h, d))
    o2 = jax.random.normal(ks[1], (nq, h, d))
    m1 = jax.random.normal(ks[2], (nq, h)) * 3
    m2 = jax.random.normal(ks[3], (nq, h)) * 3
    l1 = jnp.abs(jax.random.normal(ks[4], (nq, h))) + 0.1
    l2 = jnp.abs(jax.random.normal(ks[5], (nq, h))) + 0.1
    o_r, m_r, l_r = ref.por_ref(o1, m1, l1, o2, m2, l2)
    o_k, m_k, l_k = por.por(o1, m1, l1, o2, m2, l2, interpret=True)
    np.testing.assert_allclose(o_k, o_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(m_k, m_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-6, atol=1e-6)


def test_por_merges_split_attention():
    """POR of two KV halves == attention over the concatenation."""
    nq, h, d, n = 2, 4, 16, 48
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (nq, h, d))
    k = jax.random.normal(ks[1], (n, h, d))
    v = jax.random.normal(ks[2], (n, h, d))
    o_full, m_full, l_full = ref.pac_ref(q, k, v)
    o1, m1, l1 = ref.pac_ref(q, k[:20], v[:20])
    o2, m2, l2 = ref.pac_ref(q, k[20:], v[20:])
    o, m, l = ref.por_ref(o1, m1, l1, o2, m2, l2)
    np.testing.assert_allclose(o, o_full, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(l * jnp.exp(m),
                               l_full * jnp.exp(m_full), rtol=1e-4)


# --------------------------------------------------------------------- #
# FlashDecoding baseline kernel
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("chunk", [64, 256])
def test_flash_decode_vs_ref(hq, hkv, chunk):
    B, d, L = 3, 16, 200
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, hq, d))
    k = jax.random.normal(ks[1], (B, L, hkv, d))
    v = jax.random.normal(ks[2], (B, L, hkv, d))
    lens = jnp.asarray([200, 77, 1])
    o_fd = flash_decode.flash_decode(q, k, v, lens, chunk=chunk,
                                     interpret=True)
    o_ref = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(o_fd, o_ref, rtol=1e-5, atol=1e-5)


def test_flash_decode_window():
    B, hq, hkv, d, L = 2, 4, 2, 16, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, hq, d))
    k = jax.random.normal(ks[1], (B, L, hkv, d))
    v = jax.random.normal(ks[2], (B, L, hkv, d))
    lens = jnp.asarray([128, 90])
    o_fd = flash_decode.flash_decode(q, k, v, lens, chunk=64, window=32,
                                     interpret=True)
    o_ref = ref.decode_attention_ref(q, k, v, lens, window=32)
    np.testing.assert_allclose(o_fd, o_ref, rtol=1e-5, atol=1e-5)
