"""Smoke-run every script in ``examples/`` (documented entry points
must not rot).

Each example runs as a subprocess with small shapes; the heavyweight
end-to-end serving demo is marked ``slow`` (nightly CI runs it).  A
guard test fails when a new example is added without a smoke test
here.
"""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = ROOT / "examples"

# example file -> the test that covers it (guard below keeps this total)
COVERED = {"quickstart.py", "train_lm.py", "tree_speculation.py",
           "serve_docqa.py"}


def run_example(name: str, *args: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / name), *args],
        capture_output=True, text=True, timeout=timeout, cwd=str(ROOT),
        env=env)
    assert proc.returncode == 0, (
        f"{name} failed ({proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def test_every_example_is_covered():
    present = {p.name for p in EXAMPLES.glob("*.py")}
    assert present == COVERED, (
        f"examples/ changed; update tests/test_examples.py "
        f"(uncovered: {present - COVERED}, stale: {COVERED - present})")


def test_quickstart_runs():
    out = run_example("quickstart.py")
    assert "reduction" in out              # the IO-savings punchline
    assert "vs ref max |err|" in out       # backend sweep ran


def test_train_lm_runs(tmp_path):
    out = run_example("train_lm.py", "--steps", "6", "--batch", "2",
                      "--seq", "32", "--ckpt-dir", str(tmp_path))
    assert "done in" in out
    assert "loss" in out


def test_tree_speculation_runs():
    out = run_example("tree_speculation.py")
    assert "match the dense oracle" in out   # plan-level property
    assert "streams identical" in out        # engine speculative mode


@pytest.mark.slow
def test_serve_docqa_runs():
    out = run_example("serve_docqa.py", timeout=1800)
    assert "codec == hydragen == flash outputs: OK" in out
    assert "preemption + chunked prefill) outputs: OK" in out
    assert "SPMD mesh engine outputs: OK" in out
