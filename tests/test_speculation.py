"""Speculative tree-decoding tests (DESIGN.md §10).

Covers the whole draft-propose / tree-verify / accept-rollback loop:

* proposer determinism + draft-tree bounds;
* the public forest draft API (``add_node`` / ``add_draft`` /
  ``detach_request`` / ``prune_leaf``);
* the multi-query verify plan vs a per-branch dense oracle (the
  ``examples/tree_speculation.py`` property, kept under pytest);
* end-to-end differential: with the deterministic proposer,
  speculative greedy streams are byte-identical to non-speculative
  decode for every registered backend, eager AND fused;
* acceptance quality: mean accepted length > 1 and dispatch count
  strictly below one-per-token on a repetitive workload;
* allocator/forest leak checks after draft rollback, after evicting a
  request mid-speculation, and after releasing mid-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel
from repro.kernels import ops, ref, registry
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine, RUNNING
from repro.serving.speculation import NGramProposer, SpecConfig, accept_walk

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
PAGE = 8

# repetitive workload: the self-drafting n-gram proposer must get
# traction (random-init models settle into repetitive greedy streams,
# which the proposer then predicts)
PATTERN = [5, 7, 11, 13]
REP_PROMPT = (PATTERN * 6)[:24]
REP_MAX_NEW = 12


def run_engine(backend="codec-xla", *, spec=None, fused=False,
               prompts=(REP_PROMPT,), max_new=REP_MAX_NEW,
               num_pages=256, prefill_chunk=None, release_at=None):
    """Run prompts to completion; returns (streams, stats, engine-less).

    Always asserts the allocator/forest are leak-free after releasing
    every request (the §10 invariant: draft trees never outlive their
    verify step)."""
    eng = DecodeEngine(CFG, PARAMS, page_size=PAGE, num_pages=num_pages,
                       backend=backend, max_q=8, temperature=0.0,
                       fused=fused, speculative=spec,
                       prefill_chunk=prefill_chunk)
    rids = [eng.add_request(list(p), max_new=max_new) for p in prompts]
    for step in range(200):
        if release_at is not None and step == release_at and rids:
            eng.release(rids[-1])      # drop one mid-run (mid-speculation)
            rids = rids[:-1]
        if not eng.has_work():
            break
        eng.step()
    assert not eng.has_work(), "workload did not finish"
    outs = [list(eng.requests[r].generated) for r in rids]
    stats = dict(eng.stats)
    assert not eng._drafts, "draft state leaked past a step"
    for r in list(eng.requests):
        eng.release(r)
    assert eng.pool.num_free == eng.pool.num_pages, "leaked pages"
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}, "leaked forest nodes"
    return outs, stats


_BASE = {}


def baseline(prompts=(REP_PROMPT,), max_new=REP_MAX_NEW):
    key = (tuple(map(tuple, prompts)), max_new)
    if key not in _BASE:
        _BASE[key] = run_engine("ref", prompts=prompts, max_new=max_new)[0]
    return _BASE[key]


# --------------------------------------------------------------------- #
# proposer
# --------------------------------------------------------------------- #
def test_proposer_deterministic_and_bounded():
    cfg = SpecConfig(depth=3, branch=2, max_nodes=5, ngram=2)
    prop = NGramProposer(cfg)
    seq = [1, 2, 3, 9, 1, 2, 3, 4, 1, 2, 3]
    a = prop.propose(seq)
    assert a == prop.propose(seq), "must be deterministic"
    assert a, "repetitive sequence must draft"
    assert sum(len(c) for c in a) <= cfg.max_nodes
    assert len(a) <= cfg.branch
    assert all(len(c) <= cfg.depth for c in a)
    firsts = [c[0] for c in a]
    assert len(firsts) == len(set(firsts)), "branches fork on first token"
    # most recent match wins: after [1,2,3] the recent continuation is 4
    assert a[0][0] == 4
    # budget cap trims totals
    capped = prop.propose(seq, max_tokens=2)
    assert sum(len(c) for c in capped) <= 2


def test_proposer_no_match():
    prop = NGramProposer(SpecConfig())
    assert prop.propose([1, 2, 3, 4, 5]) == []   # all tokens distinct
    assert prop.propose([7]) == []               # too short
    assert prop.propose([]) == []


# --------------------------------------------------------------------- #
# forest draft API
# --------------------------------------------------------------------- #
def test_tree_draft_grow_prune_roundtrip():
    f = tree_mod.PrefixForest(4)
    trunk = f.add_node(tree_mod.ROOT_ID, 8)
    leaf = f.add_node(trunk.id, 4, np.arange(4, dtype=np.int32))
    f.attach_request(0, leaf.id)
    d1 = f.add_draft(leaf.id, 42)
    d2 = f.add_draft(d1.id, 43)
    sib = f.add_draft(leaf.id, 44)           # sibling branch
    for virt, node in [(-2, d1), (-3, d2), (-4, sib)]:
        f.attach_request(virt, node.id)
    f.validate()
    assert d1.meta["draft"] and d1.length == 1
    assert d1.start_pos == leaf.end_pos and d2.start_pos == d1.end_pos
    assert f.context_len(-3) == leaf.end_pos + 2
    # rollback: detach virtuals, prune leaf-first
    for virt in (-2, -3, -4):
        f.detach_request(virt)
    for node in (d2, sib, d1):
        node.page_ids = [7]
        assert f.prune_leaf(node.id) == [7]
    f.validate()
    assert set(f.nodes) == {0, trunk.id, leaf.id}
    # prune refuses non-leaves / attached nodes
    with pytest.raises(AssertionError):
        f.prune_leaf(trunk.id)               # has a child
    with pytest.raises(AssertionError):
        f.prune_leaf(leaf.id)                # request attached


def test_accept_walk_greedy_rule():
    f = tree_mod.PrefixForest(4)
    leaf = f.add_node(tree_mod.ROOT_ID, 4)
    d1 = f.add_draft(leaf.id, 10)
    d2 = f.add_draft(d1.id, 11)
    wrong = f.add_draft(leaf.id, 99)
    argmax = {leaf.id: 10, d1.id: 11, d2.id: 12, wrong.id: 0}
    acc, fin = accept_walk(f, leaf.id, argmax.__getitem__, room=8)
    assert acc == [d1.id, d2.id] and fin == 12      # full match + bonus
    argmax[d1.id] = 77                              # mismatch at depth 1
    acc, fin = accept_walk(f, leaf.id, argmax.__getitem__, room=8)
    assert acc == [d1.id] and fin == 77             # correction token
    acc, fin = accept_walk(f, leaf.id, argmax.__getitem__, room=0)
    assert acc == [] and fin == 10                  # room cap


def test_match_skips_draft_nodes():
    """Regression: ``_match_child`` descended into ``meta["draft"]``
    nodes, so ``match_len`` (admission sizing) and ``insert_tokens``
    could match a new request into another request's *unverified*
    draft tokens — which may be rolled back after the verify step."""
    f = tree_mod.PrefixForest(1)       # page 1: single-token drafts match
    f.insert_tokens(0, np.asarray([5, 6, 7], np.int32))
    leaf = f.nodes[f.leaf_of[0]]
    d = f.add_draft(leaf.id, 8)
    f.attach_request(-2, d.id)
    # pure match must stop at the committed frontier (pre-fix: 4)
    assert f.match_len(np.asarray([5, 6, 7, 8, 9], np.int32)) == 3
    # insertion must fork a committed sibling, not ride the draft
    f.insert_tokens(1, np.asarray([5, 6, 7, 8], np.int32))
    assert all(not n.meta.get("draft") for n in f.path(1))
    # the draft tree still rolls back cleanly afterwards
    f.detach_request(-2)
    f.prune_leaf(d.id)
    f.validate()


def test_admission_concurrent_with_inflight_draft_tree():
    """A request admitted while another request's draft tree is in
    flight must not share the draft KV: pre-fix its radix insertion
    attached it through a draft node, and the verify step's rollback
    then hit ``prune_leaf`` asserts (request/children on a draft)."""
    eng = DecodeEngine(CFG, PARAMS, page_size=1, num_pages=256,
                       backend="codec-xla", max_q=8, temperature=0.0,
                       speculative=SpecConfig())
    r0 = eng.add_request(list(REP_PROMPT), max_new=8)
    for _ in range(4):
        eng.step()
    assert eng.requests[r0].state == RUNNING
    # hold an in-flight draft tree open, exactly as mid-verify
    eng._grow_drafts([r0])
    st = eng._drafts.get(r0)
    assert st is not None and st.nodes, "repetitive stream must draft"
    draft_tok = int(eng.forest.nodes[st.nodes[0]].tokens[0])
    # a second request arrives whose prompt extends into the draft
    committed = list(eng.requests[r0].seq)
    r1 = eng.add_request(committed + [draft_tok, 251], max_new=2)
    path1 = eng.forest.path(r1)
    assert all(not n.meta.get("draft") for n in path1)
    # the draft tree must still roll back cleanly (pre-fix: AssertionError)
    eng._rollback_drafts(r0)
    eng.forest.validate()
    eng.run(96)
    assert len(eng.requests[r0].generated) == 8
    assert len(eng.requests[r1].generated) == 2
    for q in list(eng.requests):
        eng.release(q)
    assert eng.pool.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}


# --------------------------------------------------------------------- #
# verify plan vs per-branch dense oracle (from examples/tree_speculation)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["codec-xla", "hydragen", "flash"])
def test_verify_plan_branch_heads_vs_oracle(backend):
    page, trunk_len, depth, arity = 8, 4 * 8, 3, 2
    h_q, h_kv, d = 4, 2, 16
    forest = tree_mod.PrefixForest(page)
    trunk = forest.add_node(tree_mod.ROOT_ID, trunk_len)
    frontier = [trunk]
    for _ in range(depth):
        frontier = [forest.add_node(n.id, page)
                    for n in frontier for _ in range(arity)]
    for rid, leaf in enumerate(frontier):
        forest.attach_request(rid, leaf.id)
    forest.validate()
    B = len(frontier)
    pool_pages = plan_mod.assign_dense_pages(forest)
    cm = CostModel(h_q, h_kv, d, page_size=page)
    be = registry.get(backend)
    plan = plan_mod.build_verify_plan(forest, cm, {r: r for r in range(B)},
                                      num_lanes=2, max_q=B,
                                      kind=be.plan_kind)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, h_q, d))
    k_pool = jax.random.normal(kk, (pool_pages, page, h_kv, d))
    v_pool = jax.random.normal(kv, (pool_pages, page, h_kv, d))
    out = be(q, k_pool, v_pool, plan)
    for rid in range(B):
        ks, vs = [], []
        for node in forest.path(rid):
            for j, pg in enumerate(node.page_ids):
                take = min(page, node.length - j * page)
                ks.append(k_pool[pg][:take])
                vs.append(v_pool[pg][:take])
        kd, vd = jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)
        o_ref, _, _ = ref.pac_ref(q[rid][None], kd, vd)
        assert float(jnp.abs(out[rid] - o_ref[0]).max()) < 1e-5, rid


# --------------------------------------------------------------------- #
# end-to-end differential: spec streams == plain greedy, every backend
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("backend", registry.names())
def test_spec_stream_identical(backend, fused):
    got, stats = run_engine(backend, spec=SpecConfig(), fused=fused)
    assert got == baseline(), (backend, fused)
    assert stats["spec_steps"] >= 1


def test_spec_acceptance_and_dispatch_count():
    """The §10 acceptance criteria on a repetitive workload: drafts are
    accepted (mean accepted length > 1 token/dispatch) and the engine
    dispatches strictly fewer times than it commits tokens."""
    got, stats = run_engine("codec-xla", spec=SpecConfig())
    total_tokens = sum(len(o) for o in got)
    dispatches = stats["spec_steps"]
    assert stats["spec_accepted"] >= 1, stats
    assert dispatches < total_tokens, (dispatches, total_tokens)
    # mean committed tokens per verify dispatch strictly above one
    assert total_tokens / dispatches > 1.0
    assert stats["spec_proposed"] >= stats["spec_accepted"]


@pytest.mark.parametrize("fused", [False, True])
def test_spec_sliding_window_arch(fused):
    """Sliding-window layers route through per-window verify plans
    (window pruning in ``build_verify_plan``, ``win_slot`` routing in
    the fused dispatch); streams must still match plain greedy."""
    cfg = smoke_config("gemma3-1b")         # attn_local + attn hybrid
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def serve(spec):
        eng = DecodeEngine(cfg, params, page_size=PAGE, num_pages=256,
                           backend="codec-xla", max_q=8, temperature=0.0,
                           fused=fused, speculative=spec)
        r = eng.add_request(list(REP_PROMPT), max_new=REP_MAX_NEW)
        eng.run(96)
        out = list(eng.requests[r].generated)
        stats = dict(eng.stats)
        for q in list(eng.requests):
            eng.release(q)
        assert eng.pool.num_free == eng.pool.num_pages
        return out, stats

    base, _ = serve(None)
    got, stats = serve(SpecConfig())
    assert got == base
    assert stats["spec_steps"] < len(got), "window arch must accept drafts"


def test_spec_multi_request_shared_prefix():
    """Branch-head lanes of several requests share the trunk read in one
    verify plan; streams still match the non-speculative oracle."""
    rng = np.random.default_rng(0)
    doc = (list(rng.integers(0, CFG.vocab_size, 8)) * 3)[:24]
    prompts = [doc + list(rng.integers(0, CFG.vocab_size, 2))
               for _ in range(3)]
    base = baseline(prompts=tuple(map(tuple, prompts)), max_new=8)
    for fused in (False, True):
        got, _ = run_engine("codec-xla", spec=SpecConfig(), fused=fused,
                            prompts=prompts, max_new=8)
        assert got == base, fused


# --------------------------------------------------------------------- #
# memory pressure + rollback
# --------------------------------------------------------------------- #
def test_spec_under_pressure_with_eviction():
    """Undersized pool + chunked prefill under speculative mode: the
    engine preempts-and-recomputes and still matches the unconstrained
    oracle; every draft page is back in the free list at the end."""
    doc = (PATTERN * 12)[:48]
    prompts = [doc + [100 + 3 * i + j for j in range(3)]
               for i in range(4)]
    base = baseline(prompts=tuple(map(tuple, prompts)), max_new=12)
    # max_nodes=1 keeps the draft admission reserve small enough that
    # all four requests run concurrently, so decode growth (not just
    # draft pressure) exhausts the 9-page pool and forces preemption
    got, stats = run_engine("codec-xla", spec=SpecConfig(max_nodes=1),
                            prompts=prompts, max_new=12,
                            num_pages=9, prefill_chunk=8)
    assert got == base
    assert stats["preempted"] >= 1, stats
    assert stats["spec_accepted"] >= 1, stats


def test_preempt_mid_speculation_releases_drafts():
    """Directly evict a request while its draft tree is live: the draft
    nodes, virtual queries, and pages must all be released."""
    eng = DecodeEngine(CFG, PARAMS, page_size=PAGE, num_pages=64,
                       backend="codec-xla", max_q=8, temperature=0.0,
                       speculative=SpecConfig())
    r = eng.add_request(list(REP_PROMPT), max_new=12)
    for _ in range(6):
        eng.step()
    rows = [q for q in eng.requests if eng.requests[q].state == RUNNING]
    assert rows == [r]
    eng._grow_drafts(rows)
    assert r in eng._drafts and eng._drafts[r].nodes, \
        "repetitive stream must draft"
    n_draft_pages = len(eng._drafts[r].nodes)
    used_before = eng.pool.allocator.num_used
    eng._preempt(r)
    assert r not in eng._drafts
    assert all(not n.meta.get("draft") for n in eng.forest.nodes.values())
    assert eng.pool.allocator.num_used <= used_before - n_draft_pages
    eng.pool.allocator.check()
    # the preempted request resumes and finishes with the same stream
    eng.run(64)
    assert list(eng.requests[r].generated) == baseline()[0]
    for q in list(eng.requests):
        eng.release(q)
    assert eng.pool.num_free == eng.pool.num_pages
    assert set(eng.forest.nodes) == {0}


def test_release_mid_run_leak_free():
    prompts = [REP_PROMPT, list(REP_PROMPT[:16])]
    outs, _ = run_engine("codec-xla", spec=SpecConfig(), prompts=prompts,
                         release_at=4)
    assert len(outs) == 1        # released request dropped cleanly


# --------------------------------------------------------------------- #
# gates
# --------------------------------------------------------------------- #
def test_spec_rejects_unsupported_modes():
    with pytest.raises(ValueError, match="greedy-only"):
        DecodeEngine(CFG, PARAMS, page_size=PAGE, backend="codec-xla",
                     temperature=0.7, speculative=True)
    mcfg = smoke_config("mamba2-2.7b")
    mparams = T.init_params(mcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="Mamba"):
        DecodeEngine(mcfg, mparams, page_size=PAGE, backend="codec-xla",
                     speculative=True)


def test_spec_max_new_exact_cap():
    """Accepted drafts never overshoot max_new (commit truncates)."""
    for max_new in (1, 2, 3):
        base = baseline(max_new=max_new)
        got, _ = run_engine("codec-xla", spec=SpecConfig(),
                            max_new=max_new)
        assert got == base, max_new
        assert all(len(o) == max_new for o in got)
