"""Launch layer: sharding rules, hlo parsing, cost model, mini dry-run."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCH, get_config, smoke_config
from repro.core.cost_model import CostModel, profile
from repro.launch import hlo_stats
from repro.launch import sharding as sh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------- #
class _FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes
        self.axis_names = tuple(sizes)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + [PAPER_ARCH])
def test_param_specs_legal_for_all_archs(arch):
    """Every full-config param gets a spec whose axes divide its dims."""
    from repro.models import transformer as T
    cfg = get_config(arch)
    params_sds = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                jax.random.PRNGKey(0))
    mesh = _FakeMesh({"data": 16, "model": 16})

    def check(path, leaf):
        ps = sh.param_pspec(
            "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path), len(leaf.shape), cfg)
        spec = sh.legalize(ps, leaf.shape, mesh)
        for i, entry in enumerate(spec):
            if entry is not None:
                assert leaf.shape[i] % sh._axis_size(mesh, entry) == 0
        return spec

    specs = jax.tree_util.tree_map_with_path(check, params_sds)
    # big weights must actually be sharded (not silently replicated)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    sds_flat = jax.tree_util.tree_flatten_with_path(params_sds)[0]
    for (path, spec), (_, leaf) in zip(flat, sds_flat):
        n = int(np.prod(leaf.shape))
        if n >= (1 << 22):  # >= 4M params
            assert any(e is not None for e in spec), (path, leaf.shape)


def test_embed_and_attn_specs():
    cfg = get_config("qwen3-4b")
    # vocab->data, d->model (§Perf: the transposed layout removed the
    # token-gather permute chain; see EXPERIMENTS.md)
    assert tuple(sh.param_pspec("embed", 2, cfg)) == ("data", "model")
    assert tuple(sh.param_pspec("blocks/sub0/attn/wq/w", 3, cfg)) \
        == (None, "data", "model")
    assert tuple(sh.param_pspec("blocks/sub0/attn/wo/w", 3, cfg)) \
        == (None, "model", "data")
    assert tuple(sh.param_pspec("blocks/sub0/ln/scale", 2, cfg)) \
        == ()


def test_moe_expert_parallel_spec():
    cfg = get_config("kimi-k2-1t-a32b")
    assert tuple(sh.param_pspec("blocks/sub0/ffn/wi", 4, cfg)) \
        == (None, "model", "data", None)
    assert tuple(sh.param_pspec("blocks/sub0/ffn/router", 3, cfg)) \
        == (None, "data", None)


# --------------------------------------------------------------------- #
# HLO collective parsing
# --------------------------------------------------------------------- #
HLO_SAMPLE = textwrap.dedent("""\
    ENTRY main {
      %p0 = f32[128,64]{1,0} parameter(0)
      %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
      %ag = bf16[256,64]{1,0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
      %rs = f32[16,64]{1,0} reduce-scatter(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
      %cp = f32[128,64]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
      %ars = (f32[32]{0}, f32[32]{0}) all-reduce-start(%p0), replica_groups={{0,1}}
      %ard = f32[32]{0} all-reduce-done(%ars)
      %dot = f32[128,128]{1,0} dot(%p0, %p0)
    }
""")


def test_collect_collectives_counts_and_bytes():
    st = hlo_stats.collect_collectives(HLO_SAMPLE, total_devices=16)
    assert st.count["all-reduce"] == 2      # plain + start (done excluded)
    assert st.count["all-gather"] == 1
    assert st.count["reduce-scatter"] == 1
    assert st.count["collective-permute"] == 1
    # all-reduce: 128*64*4 bytes, group 4 -> wire 2*(3/4)*32768
    ar_plain = 2 * 0.75 * 128 * 64 * 4
    ar_start = 2 * 0.5 * 32 * 4          # group 2, result half = f32[32]
    assert abs(st.link_bytes["all-reduce"] - (ar_plain + ar_start)) < 1
    # all-gather bf16[256,64] group 8 -> (7/8)*32768
    assert abs(st.link_bytes["all-gather"] - 0.875 * 256 * 64 * 2) < 1
    # permute: full size
    assert abs(st.link_bytes["collective-permute"] - 128 * 64 * 4) < 1


def test_group_size_parsing():
    assert hlo_stats._group_size("replica_groups={{0,1,2}}", 99) == 3
    assert hlo_stats._group_size("replica_groups=[4,64]<=[256]", 99) == 64
    assert hlo_stats._group_size("no groups here", 7) == 7


# --------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------- #
def test_cost_model_monotone_and_bounds():
    cm = CostModel(32, 8, 128, page_size=64)
    assert cm(1, 1024) < cm(1, 8192) < cm(1, 65536)
    assert cm(1, 4096) <= cm(64, 4096)
    # long-thin decode task is memory bound; fat task compute bound
    assert cm.bound(1, 8192) == "memory"
    assert cm.bound(512, 8192) == "compute"


def test_cost_model_table_interpolation():
    cm0 = CostModel(8, 2, 64)
    table = {(1, 512): 1.0, (1, 2048): 3.0, (4, 512): 2.0, (4, 2048): 6.0}
    cm = CostModel(8, 2, 64, table=table)
    for k, v in table.items():
        assert abs(cm(*k) - v) < 1e-9      # exact at grid points
    mid = cm(2, 1024)                      # log-bilinear midpoint
    assert 1.0 < mid < 6.0


def test_profile_builds_usable_table():
    cm = CostModel(4, 2, 16)
    calls = []
    cm2 = profile(cm, lambda nq, n: calls.append((nq, n)),
                  n_qs=(1, 2), ns=(64, 128), repeats=1)
    assert cm2._grid is not None
    assert cm2(1, 64) >= 0


# --------------------------------------------------------------------- #
# mini dry-run in a subprocess (4 forced host devices)
# --------------------------------------------------------------------- #
MINI_DRYRUN = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.launch import sharding as sh
    from repro.training import trainer
    from repro.training.optimizer import cosine_schedule, make_optimizer

    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # added in newer jax
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
    cfg = smoke_config("qwen2.5-14b")
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 10))
    step = trainer.make_train_step(cfg, opt, remat=False)
    state_sds = trainer.abstract_state(cfg, opt)
    psh = sh.params_shardings(state_sds.params, mesh, cfg)
    state = trainer.TrainState(
        jax.ShapeDtypeStruct((), jnp.int32, sharding=sh.replicated(mesh)),
        sh.with_sharding(state_sds.params, psh),
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sh.replicated(mesh)),
            state_sds.opt_state))
    bshd = sh.batch_sharding(mesh, 2, 4)
    tok = jax.ShapeDtypeStruct((4, 16), jnp.int32, sharding=bshd)
    with mesh:
        compiled = jax.jit(step).lower(state, (tok, tok)).compile()
    print("MEM", compiled.memory_analysis().temp_size_in_bytes)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):                # old jax wraps it in a list
        ca = ca[0]
    print("FLOPS", ca["flops"])
    print("DRYRUN_OK")
""")


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    script = tmp_path / "mini.py"
    script.write_text(MINI_DRYRUN)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "DRYRUN_OK" in r.stdout, r.stderr[-2000:]
