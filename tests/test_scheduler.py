"""Task division + LPT scheduling properties (paper §5.1).

Deterministic hand-picked task sets always run; hypothesis widens the
sweep when installed (budget set in conftest)."""

import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS
from repro.core.cost_model import CostModel, HardwareSpec
from repro.core.scheduler import (AdmissionController, AdmissionPolicy,
                                  SubTask, TaskSpec, divide_and_schedule,
                                  divide_task, lpt, naive_divide)


CM = CostModel(8, 2, 64, page_size=64)


# --------------------------------------------------------------------- #
# property checks
# --------------------------------------------------------------------- #
def _check_coverage(tasks, lanes):
    sched = divide_and_schedule(tasks, CM, lanes, page_size=64)
    # every task's KV range is exactly partitioned by its subtasks
    by_node = {}
    for s in sched.subtasks:
        by_node.setdefault(s.node_id, []).append(s)
    for t in tasks:
        subs = sorted(by_node[t.node_id], key=lambda s: (s.q_lo, s.kv_lo))
        qs = sorted({(s.q_lo, s.q_hi) for s in subs})
        # q slices tile [0, n_q)
        assert qs[0][0] == 0 and qs[-1][1] == t.n_q
        for (a, b), (c, d) in zip(qs, qs[1:]):
            assert b == c
        for qlo, qhi in qs:
            kvs = sorted([(s.kv_lo, s.kv_hi) for s in subs
                          if (s.q_lo, s.q_hi) == (qlo, qhi)])
            assert kvs[0][0] == 0 and kvs[-1][1] == t.n
            for (a, b), (c, d) in zip(kvs, kvs[1:]):
                assert b == c
            # page alignment of interior boundaries
            for lo, hi in kvs:
                assert lo % 64 == 0
    # every subtask is assigned exactly one lane
    assert len(sched.lane_of) == len(sched.subtasks)
    assert all(0 <= l < lanes for l in sched.lane_of)
    # makespan equals the max lane cost
    lane_cost = [0.0] * lanes
    for i, l in enumerate(sched.lane_of):
        lane_cost[l] += sched.subtasks[i].cost
    assert abs(max(lane_cost) - sched.makespan) < 1e-12


def _check_makespan_beats_or_matches_single_lane(tasks, lanes):
    multi = divide_and_schedule(tasks, CM, lanes, page_size=64)
    single = divide_and_schedule(tasks, CM, 1, page_size=64)
    assert multi.makespan <= single.makespan * 1.001


def _check_lpt_guarantee(costs, lanes):
    """List scheduling: makespan <= avg + max <= 2 x the trivial lower
    bound (Graham 1966 gives 4/3 vs OPT; vs the bound only 2x holds)."""
    subs = [SubTask(0, 0, 1, 0, 64, c) for c in costs]
    lane_of, lane_cost = lpt(subs, lanes)
    opt_lb = max(max(costs), sum(costs) / lanes)   # trivial lower bound
    assert max(lane_cost) <= 2 * opt_lb + 1e-9


# --------------------------------------------------------------------- #
# deterministic hand-picked cases
# --------------------------------------------------------------------- #
FIXED_TASK_SETS = {
    "single": [TaskSpec(1, 1, 64)],
    "doc_qa": [TaskSpec(1, 32, 100_000)] + [
        TaskSpec(i + 2, 1, 64) for i in range(7)],
    "uniform": [TaskSpec(i + 1, 4, 2048) for i in range(6)],
    "skewed": [TaskSpec(1, 16, 65536), TaskSpec(2, 2, 512),
               TaskSpec(3, 1, 8191), TaskSpec(4, 32, 64)],
    "unaligned": [TaskSpec(1, 3, 100), TaskSpec(2, 5, 63),
                  TaskSpec(3, 7, 4097)],
}


@pytest.mark.parametrize("name", sorted(FIXED_TASK_SETS))
@pytest.mark.parametrize("lanes", [1, 3, 8])
def test_divide_and_schedule_coverage_fixed(name, lanes):
    _check_coverage(FIXED_TASK_SETS[name], lanes)


@pytest.mark.parametrize("name", sorted(FIXED_TASK_SETS))
@pytest.mark.parametrize("lanes", [2, 8])
def test_makespan_beats_or_matches_single_lane_fixed(name, lanes):
    _check_makespan_beats_or_matches_single_lane(FIXED_TASK_SETS[name],
                                                 lanes)


@pytest.mark.parametrize("costs,lanes", [
    ([1.0], 1),
    ([5.0, 1.0, 1.0, 1.0, 1.0, 1.0], 3),
    ([0.001, 10.0, 4.9, 5.1, 2.5, 2.5], 2),
    (list(np.linspace(0.1, 3.0, 17)), 8),
])
def test_lpt_guarantee_fixed(costs, lanes):
    _check_lpt_guarantee(costs, lanes)


# --------------------------------------------------------------------- #
# property-based sweeps (hypothesis only)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    from hypothesis import given, strategies as st

    @st.composite
    def task_sets(draw):
        t = draw(st.integers(1, 12))
        return [TaskSpec(i + 1,
                         draw(st.integers(1, 32)),
                         draw(st.integers(1, 8192)))
                for i in range(t)]

    @given(task_sets(), st.integers(1, 8))
    def test_divide_and_schedule_coverage(tasks, lanes):
        _check_coverage(tasks, lanes)

    @given(task_sets(), st.integers(2, 8))
    def test_makespan_beats_or_matches_single_lane(tasks, lanes):
        _check_makespan_beats_or_matches_single_lane(tasks, lanes)

    @given(st.lists(st.floats(0.001, 10.0), min_size=1, max_size=40),
           st.integers(1, 8))
    def test_lpt_guarantee(costs, lanes):
        _check_lpt_guarantee(costs, lanes)


# --------------------------------------------------------------------- #
# fixed regressions
# --------------------------------------------------------------------- #
def test_divider_respects_caps():
    t = TaskSpec(1, 100, 10000)
    subs = divide_task(t, 3, CM, page_size=64, max_q=32)
    assert all(s.n_q <= 32 for s in subs)
    sched = divide_and_schedule([t], CM, 4, 64, max_kv_per_task=2048,
                                max_q_per_task=32)
    assert all(s.n <= 2048 for s in sched.subtasks)
    assert all(s.n_q <= 32 for s in sched.subtasks)


def test_skewed_forest_balances_better_than_naive():
    """Paper Fig. 10: adaptive division beats a fixed division count."""
    # one huge shared node + many tiny ones (the doc-QA shape)
    tasks = [TaskSpec(1, 32, 100_000)] + [
        TaskSpec(i + 2, 1, 64) for i in range(31)]
    lanes = 8
    sched = divide_and_schedule(tasks, CM, lanes, page_size=64,
                                max_kv_per_task=None)
    naive1 = naive_divide(tasks, 1, CM, page_size=64)
    _, naive_cost = lpt(naive1, lanes)
    # adaptive must beat no-division scheduling clearly
    assert sched.makespan < max(naive_cost) * 0.7
    # and the imbalance must be small
    avg = sum(l for l in sched.lane_costs) / lanes
    assert sched.makespan <= 1.5 * avg


def test_cost_lower_bound_holds():
    tasks = [TaskSpec(1, 4, 4096), TaskSpec(2, 2, 1024)]
    sched = divide_and_schedule(tasks, CM, 4, 64)
    total = sum(CM(t.n_q, t.n) for t in tasks)
    assert sched.makespan >= total / 4 * 0.999  # Eq. 4


# --------------------------------------------------------------------- #
# admission control (serving under memory pressure)
# --------------------------------------------------------------------- #
def _controller(**kw):
    return AdmissionController(AdmissionPolicy(**kw), CM, page_size=64)


def test_admission_policy_validation():
    with pytest.raises(ValueError):
        AdmissionPolicy(prefill_chunk="bogus")
    with pytest.raises(ValueError):
        AdmissionPolicy(prefill_chunk=0)
    AdmissionPolicy(prefill_chunk="auto")
    AdmissionPolicy(prefill_chunk=128)


def test_admission_queue_is_fcfs_with_preempted_at_front():
    c = _controller()
    for r in (0, 1, 2):
        c.push(r)
    assert c.pop() == 0
    c.requeue(0)                      # preempted: back to the head
    assert [c.pop() for _ in range(3)] == [0, 1, 2]
    c.push(5)
    c.remove(5)
    c.remove(5)                       # removing a missing rid is a no-op
    assert len(c) == 0


def test_prefill_budget_modes():
    # None -> unlimited; fixed int -> that chunk
    assert _controller().prefill_budget([128, 256]) is None
    assert _controller(prefill_chunk=96).prefill_budget([128]) == 96
    auto = _controller(prefill_chunk="auto")
    # nothing decoding: nothing to starve, budget unlimited
    assert auto.prefill_budget([]) is None
    b = auto.prefill_budget([256] * 4)
    assert b is not None and b >= 64          # at least one page
    assert b <= AdmissionPolicy().max_auto_chunk


def test_auto_budget_scales_with_decode_batch():
    """A heavier decode batch affords a larger interleaved prefill chunk
    (the budget is a fraction of the decode-step cost, Sarathi-style)."""
    auto = _controller(prefill_chunk="auto")
    small = auto.prefill_budget([128])
    large = auto.prefill_budget([4096] * 16)
    assert large >= small
    # and the chunk's cost really is bounded by the balance ratio
    ctx = [4096] * 16
    decode_cost = sum(CM(1, c) for c in ctx)
    mean_ctx = int(sum(ctx) / len(ctx))
    if large > 64:   # cost bound only binds above the one-page floor
        assert CM(large, mean_ctx + large) <= \
            AdmissionPolicy().balance_ratio * decode_cost * 2.01
