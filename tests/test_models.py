"""Per-arch smoke tests + decode parity + SSD oracle checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCH, get_config,
                           smoke_config)
from repro.models import layers as L, mamba as M, transformer as T

ALL_ARCHS = ASSIGNED_ARCHS + [PAPER_ARCH]


def _extras(cfg, B):
    kw = {}
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = 0.01 * jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.frontend_seq, cfg.d_model))
    if cfg.frontend == "audio":
        kw["encoder_embeds"] = 0.01 * jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.frontend_seq, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    from repro.training import trainer
    from repro.training.optimizer import cosine_schedule, make_optimizer

    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    params = T.init_params(cfg, key)
    logits, aux, _ = T.forward(params, cfg, toks, **_extras(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 10))
    step = trainer.make_train_step(
        cfg, opt, remat=False,
        extras_fn=(lambda t: _extras(cfg, t.shape[0]))
        if cfg.frontend != "none" else None)
    state = trainer.init_state(cfg, opt, key)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    state2, metrics = jax.jit(step)(state, (toks, labels))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma3-1b", "gemma-2b",
                                  "mamba2-2.7b", "jamba-v0.1-52b",
                                  "kimi-k2-1t-a32b"])
def test_decode_parity(arch):
    """prefill + decode_step == full forward at the last position."""
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 13), 0,
                              cfg.vocab_size)
    logits_full, _, _ = T.forward(params, cfg, toks)
    _, cache, clen = T.prefill(params, cfg, toks[:, :-1], max_len=16)
    logits_dec, _ = T.decode_step(params, cfg, toks[:, -1:], cache, clen)
    scale = float(jnp.abs(logits_full[:, -1]).max())
    np.testing.assert_allclose(logits_dec, logits_full[:, -1],
                               rtol=1e-3, atol=1e-3 * max(scale, 1.0))


def test_decode_unroll_matches_scan():
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0,
                              cfg.vocab_size)
    _, cache, clen = T.prefill(params, cfg, toks[:, :-1], max_len=12)
    l1, _ = T.decode_step(params, cfg, toks[:, -1:], cache, clen)
    l2, _ = T.decode_step(params, cfg, toks[:, -1:], cache, clen,
                          unroll=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_forward_unroll_and_remat_match_scan():
    cfg = smoke_config("jamba-v0.1-52b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                              cfg.vocab_size)
    base, _, _ = T.forward(params, cfg, toks)
    un, _, _ = T.forward(params, cfg, toks, unroll=True)
    rm, _, _ = T.forward(params, cfg, toks, remat=True)
    lo, _, _ = T.forward(params, cfg, toks, last_only=True)
    np.testing.assert_allclose(base, un, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(base, rm, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(base[:, -1:], lo, rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_recurrence():
    """Chunked SSD (train path) == token-by-token recurrence (decode)."""
    cfg = smoke_config("mamba2-2.7b")
    p = M.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 35, cfg.d_model))
    y_par, (conv_s, ssm_s) = M.mamba_forward(p, cfg, x)
    y_seq = M.mamba_recurrent_ref(p, cfg, x)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-3, atol=2e-3)


def test_ssd_chunk_size_invariance():
    import dataclasses
    cfg = smoke_config("mamba2-2.7b")
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (1, 40, cfg.d_model))
    p = M.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    outs = []
    for chunk in (8, 16, 40):
        c2 = dataclasses.replace(cfg, ssm_chunk=chunk)
        y, _ = M.mamba_forward(p, c2, x)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)


def test_moe_no_drop_exactness():
    """capacity_factor<=0 routes every token: y == dense per-expert mix."""
    cfg = smoke_config("llama4-scout-17b-a16e")  # top-1 MoE
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model))
    y, aux = L.apply_moe(p, cfg, x)
    # dense reference: every token through its top-k experts
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    expect = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.num_experts_per_tok):
            e = int(idx[t, j])
            h = xt[t] @ p["wi"][e]
            h = L._act(h, cfg.mlp_act, cfg.d_ff)
            expect[t] += float(gate[t, j]) * np.asarray(h @ p["wo"][e])
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), expect,
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With a tight capacity some token-choices are dropped (not NaN)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config("kimi-k2-1t-a32b"),
                              capacity_factor=0.5)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = L.apply_moe(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens => output strictly smaller norm than no-drop
    cfg2 = dataclasses.replace(cfg, capacity_factor=0.0)
    y2, _ = L.apply_moe(p, cfg2, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y2)) + 1e-3


def test_loss_decreases_on_tiny_model():
    from repro.training import trainer
    from repro.training.data import DataConfig, SyntheticLM
    from repro.training.optimizer import cosine_schedule, make_optimizer

    cfg = smoke_config("gemma-2b")
    opt = make_optimizer("adamw", cosine_schedule(3e-3, 2, 50))
    step = jax.jit(trainer.make_train_step(cfg, opt, remat=False))
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4))
    losses = []
    for i in range(25):
        toks, labels = data.batch(i % 2)  # cycle 2 batches -> must fit
        state, m = step(state, (jnp.asarray(toks), jnp.asarray(labels)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                num_experts_per_tok=8),
        "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120,
                                      num_heads=40, num_kv_heads=8,
                                      d_ff=8192, vocab_size=202048,
                                      num_experts=16,
                                      num_experts_per_tok=1),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336,
                               vocab_size=65536, num_experts=16,
                               num_experts_per_tok=2),
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             num_kv_heads=8, d_ff=2048, vocab_size=51865,
                             encoder_layers=6),
        "gemma-2b": dict(num_layers=18, d_model=2048, num_heads=8,
                         num_kv_heads=1, d_ff=16384, vocab_size=256000),
        "qwen1.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=40, d_ff=27392,
                            vocab_size=152064, qkv_bias=True),
        "qwen2.5-14b": dict(num_layers=48, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=13824,
                            vocab_size=152064, qkv_bias=True),
        "gemma3-1b": dict(num_layers=26, d_model=1152, num_heads=4,
                          num_kv_heads=1, d_ff=6912, vocab_size=262144),
        "llava-next-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                               num_kv_heads=8, d_ff=20480,
                               vocab_size=64000),
        "qwen3-4b": dict(num_heads=32, num_kv_heads=8, head_dim=128),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-1b")
    kinds = [cfg.layer_kind(i).mixer for i in range(12)]
    assert kinds[:6] == ["attn_local"] * 5 + ["attn"]
    assert cfg.sliding_window == 512


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-v0.1-52b")
    mixers = [cfg.layer_kind(i).mixer for i in range(8)]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    ffns = [cfg.layer_kind(i).ffn for i in range(8)]
    assert ffns.count("moe") == 4
