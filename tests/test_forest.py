"""Property tests on the prefix forest (paper §4.1 structures)."""

import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS
from repro.core import tree as tree_mod


# --------------------------------------------------------------------- #
# radix insertion
# --------------------------------------------------------------------- #
def _check_radix_insert_invariants(bs, prompts):
    f = tree_mod.PrefixForest(bs)
    for rid, p in enumerate(prompts):
        f.insert_tokens(rid, p)
    f.validate()
    # 1. every request's path reconstructs its exact token sequence
    for rid, p in enumerate(prompts):
        toks = np.concatenate([n.tokens for n in f.path(rid)
                               if n.tokens is not None and len(n.tokens)])
        np.testing.assert_array_equal(toks, p)
    # 2. sharing is page-aligned: every shared (multi-request) node with a
    #    parent boundary starts at a multiple of the page size
    for n in f.real_nodes():
        if len(n.requests) > 1:
            assert n.start_pos % bs == 0 or n.parent == tree_mod.ROOT_ID
    # 3. tree tokens <= total prompt tokens (sharing can only shrink)
    assert f.total_tokens() <= sum(len(p) for p in prompts)
    # 4. context length == prompt length
    for rid, p in enumerate(prompts):
        assert f.context_len(rid) == len(p)


def _check_identical_prompts_share_all_pages(n_req, n_pages):
    bs = 16
    prompt = np.arange(bs * n_pages, dtype=np.int32)
    f = tree_mod.PrefixForest(bs)
    for rid in range(n_req):
        f.insert_tokens(rid, prompt)
    f.validate()
    # shared tokens stored once (+ empty private leaves)
    assert f.total_tokens() == len(prompt)
    assert f.total_context() == n_req * len(prompt)
    if n_req > 1:
        assert abs(f.mean_sharing_degree() - n_req) < 1e-9


_DOC = list(range(0, 40))


@pytest.mark.parametrize("bs,prompts", [
    (4, [np.asarray(_DOC[:16] + [60, 61], np.int32),
         np.asarray(_DOC[:16] + [70, 71, 72], np.int32),
         np.asarray(_DOC[:8] + [80], np.int32)]),
    (8, [np.asarray(_DOC + [90], np.int32),
         np.asarray(_DOC[:24] + [91, 92], np.int32)]),
    (16, [np.asarray([51, 52, 53], np.int32)]),    # shorter than a page
    (5, [np.asarray(_DOC[:10] + [60], np.int32),
         np.asarray(_DOC[:10] + [60], np.int32)]),  # identical prompts
])
def test_radix_insert_invariants_fixed(bs, prompts):
    _check_radix_insert_invariants(bs, prompts)


@pytest.mark.parametrize("n_req,n_pages", [(1, 1), (2, 3), (8, 5)])
def test_identical_prompts_share_all_pages_fixed(n_req, n_pages):
    _check_identical_prompts_share_all_pages(n_req, n_pages)


if HAVE_HYPOTHESIS:
    from hypothesis import given, strategies as st

    @st.composite
    def prompt_sets(draw):
        """Prompts with controlled shared structure."""
        bs = draw(st.integers(4, 64))
        n_docs = draw(st.integers(1, 3))
        docs = [draw(st.lists(st.integers(0, 50), min_size=bs,
                              max_size=4 * bs))
                for _ in range(n_docs)]
        prompts = []
        for _ in range(draw(st.integers(1, 6))):
            doc = draw(st.sampled_from(docs))
            cut = draw(st.integers(0, len(doc)))
            tail = draw(st.lists(st.integers(51, 99), min_size=1,
                                 max_size=12))
            prompts.append(np.asarray(doc[:cut] + tail, np.int32))
        return bs, prompts

    @given(prompt_sets())
    def test_radix_insert_invariants(data):
        _check_radix_insert_invariants(*data)

    @given(st.integers(1, 8), st.integers(1, 5))
    def test_identical_prompts_share_all_pages(n_req, n_pages):
        _check_identical_prompts_share_all_pages(n_req, n_pages)


def test_append_token_forks_shared_leaf():
    bs = 4
    f = tree_mod.PrefixForest(bs)
    p = np.arange(8, dtype=np.int32)
    f.insert_tokens(0, p)
    f.insert_tokens(1, p)          # identical prompt: same leaf
    f.append_token(0, 100)
    f.append_token(1, 200)
    f.validate()
    assert f.leaf_of[0] != f.leaf_of[1]
    assert f.context_len(0) == 9 and f.context_len(1) == 9
    toks0 = np.concatenate([n.tokens for n in f.path(0)
                            if n.tokens is not None and len(n.tokens)])
    assert toks0[-1] == 100


def test_split_propagates_pins_to_both_halves():
    """Regression: ``_split`` copied ``filled``/``ssm`` metadata but
    dropped ``meta["pins"]`` on the new lower half, so a pinned prefix
    tail could be split and its lower half freed out from under the
    waiting request that pinned it."""
    bs = 4
    f = tree_mod.PrefixForest(bs)
    f.insert_tokens(0, np.arange(16, dtype=np.int32))
    # an evicted request pins its whole path, then detaches (engine
    # preemption: membership is dropped, the pin keeps the KV alive)
    for n in f.path(0):
        n.meta["pins"] = n.meta.get("pins", 0) + 1
    f.detach_request(0)
    splits = []
    f.on_split = lambda upper, lower: splits.append((upper.id, lower.id))
    # a new request sharing only the first 8 tokens splits the pinned node
    f.insert_tokens(1, np.concatenate([np.arange(8),
                                       [90, 91]]).astype(np.int32))
    f.validate()
    assert splits, "insertion must have split the pinned node"
    pinned = [n for n in f.real_nodes() if n.meta.get("pins", 0) > 0]
    # the full 16-token pinned span stays protected (pre-fix: 8)
    assert sum(n.length for n in pinned) == 16
    upper_id, lower_id = splits[0]
    assert f.nodes[upper_id].meta["pins"] == f.nodes[lower_id].meta["pins"]


def test_split_preserves_requests_and_pages():
    bs = 4
    f = tree_mod.PrefixForest(bs)
    f.insert_tokens(0, np.arange(16, dtype=np.int32))
    # second request shares the first 8 tokens only -> forces a split
    f.insert_tokens(1, np.concatenate([np.arange(8), 90 + np.arange(4)]
                                      ).astype(np.int32))
    f.validate()
    assert f.context_len(0) == 16
    assert f.context_len(1) == 12
    # the shared node has both requests
    shared = [n for n in f.real_nodes() if len(n.requests) == 2]
    assert len(shared) == 1 and shared[0].length == 8


# --------------------------------------------------------------------- #
# IO metrics (paper §4.3 complexity claim)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s_pages,u_pages", [
    (2, 1, 1), (8, 4, 2), (32, 16, 8), (5, 16, 1), (17, 1, 8)])
def test_io_ratio_equals_mean_sharing_degree(b, s_pages, u_pages):
    bs = 8
    f = tree_mod.two_level(b, s_pages * bs, u_pages * bs, bs)
    ratio = f.flash_io_bytes(2, 16) / f.codec_io_bytes(2, 16)
    assert abs(ratio - f.mean_sharing_degree()) < 1e-9
    # two-level closed form: (S + B*U)/ (S + U) per request... inverse:
    s, u = s_pages * bs, u_pages * bs
    expect = (b * (s + u)) / (s + b * u)
    assert abs(ratio - expect) < 1e-9


def test_synthetic_builders_validate():
    for f in [tree_mod.two_level(8, 128, 32, 16),
              tree_mod.full_kary(3, 2, 64, 16),
              tree_mod.degenerate(4, 32, 16),
              tree_mod.shared_ratio(8, 1024, 0.9, 16)]:
        f.validate()
        assert f.total_tokens() > 0


def test_shared_ratio_builder_hits_target():
    f = tree_mod.shared_ratio(16, 4096, 0.8, 16)
    s = max(n.length for n in f.real_nodes())
    total = f.total_tokens()
    assert abs(s / total - 0.8) < 0.1


# --------------------------------------------------------------------- #
# non-mutating radix match (admission-controller page estimation)
# --------------------------------------------------------------------- #
def test_match_len_is_page_aligned_and_pure():
    bs = 8
    f = tree_mod.PrefixForest(bs)
    doc = np.arange(100, 148, dtype=np.int32)          # 48 tokens, 6 pages
    f.insert_tokens(0, np.concatenate([doc, [1, 2, 3]]))

    def snapshot():
        return {k: (v.length, tuple(v.children)) for k, v in f.nodes.items()}

    before = snapshot()
    # full page-aligned prefix of an inserted sequence matches
    assert f.match_len(np.concatenate([doc, [9, 9]])) == 48
    # partial overlap matches only whole pages
    assert f.match_len(doc[:20]) == 16
    # mismatch on the first token matches nothing
    assert f.match_len(np.arange(500, 520, dtype=np.int32)) == 0
    # pure: the queries above caused no splits and created no nodes
    assert snapshot() == before
    f.validate()
    # match descends across chained nodes created by a split
    f.insert_tokens(1, np.concatenate([doc[:16], [7, 8]]))
    assert f.match_len(np.concatenate([doc, [1, 2, 3, 4]])) == 48
