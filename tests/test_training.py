"""Training substrate: optimizers, checkpointing, data, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import optimizer as O
from repro.training.data import DataConfig, SyntheticLM


# --------------------------------------------------------------------- #
# optimizers
# --------------------------------------------------------------------- #
def test_adamw_first_step_is_sign_sgd_like():
    opt = O.adamw(lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.5, -0.25])}
    upd, state = opt.update(grads, state, params)
    # bias-corrected first step: -lr * g/|g| (m/c1=g, v/c2=g^2)
    np.testing.assert_allclose(upd["w"], [-0.1, 0.1], rtol=1e-4)


def test_adafactor_factored_state_is_small():
    opt = O.adafactor(lambda s: 0.1)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    state = opt.init(params)
    assert state["slots"]["w"]["vr"].shape == (64,)
    assert state["slots"]["w"]["vc"].shape == (32,)
    assert state["slots"]["b"]["v"].shape == (7,)
    grads = {"w": jnp.ones((64, 32)), "b": jnp.ones((7,))}
    upd, state = opt.update(grads, state, params)
    assert all(bool(jnp.all(jnp.isfinite(u)))
               for u in jax.tree.leaves(upd))


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = O.clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(O.global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    f = O.cosine_schedule(1.0, warmup=10, total=110, min_ratio=0.1)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(60)) < 1.0
    assert abs(float(f(110)) - 0.1) < 1e-2


# --------------------------------------------------------------------- #
# int8 gradient compression (error feedback)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2, 5])
def test_int8_compression_error_feedback_unbiased(seed):
    """Accumulated error feedback: sum of decompressed == sum of true
    gradients up to one quantization step."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    err = jnp.zeros_like(g)
    total_dec = jnp.zeros_like(g)
    steps = 20
    for _ in range(steps):
        q, scale, err = O.compress_int8(g, err)
        total_dec = total_dec + O.decompress_int8(q, scale)
    # residual error is bounded by one quantization step
    resid = steps * g - total_dec
    max_scale = float(jnp.max(jnp.abs(g))) / 127.0 * 2
    assert float(jnp.abs(resid).max()) <= max_scale + 1e-5


def test_int8_roundtrip_small_error():
    g = jnp.linspace(-1, 1, 255)
    q, scale, err = O.compress_int8(g, jnp.zeros_like(g))
    rec = O.decompress_int8(q, scale)
    assert float(jnp.abs(rec - g).max()) <= float(scale) / 2 + 1e-6


# --------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------- #
def _tree(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {"layer": {"w": jax.random.normal(ks[0], (16, 8)),
                      "b": jax.random.normal(ks[1], (8,))},
            "step": jnp.asarray(5, jnp.int32),
            "stack": [jax.random.normal(ks[2], (4, 4))]}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save_checkpoint(d, 7, tree, num_shards=2)
    assert ckpt.latest_step(d) == 7
    restored, manifest = ckpt.load_checkpoint(d, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 7


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(d, s, _tree(s), keep=3)
    assert ckpt.all_steps(d) == [3, 4, 5]
    step, tree, _ = ckpt.load_latest(d, _tree())
    assert step == 5


def test_incomplete_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree())
    # simulate a crash mid-save at step 2: directory without manifest
    os.makedirs(os.path.join(d, "step_000002"))
    assert ckpt.latest_step(d) == 1  # atomic publish respected


def test_checkpoint_shard_reassembly_matches_single(tmp_path):
    tree = _tree()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    ckpt.save_checkpoint(d1, 1, tree, num_shards=1)
    ckpt.save_checkpoint(d2, 1, tree, num_shards=4)
    r1, _ = ckpt.load_checkpoint(d1, 1, tree)
    r2, _ = ckpt.load_checkpoint(d2, 1, tree)
    for a, b in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------- #
def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg, start_step=2)
    b0, b1, b2 = d1.batch(0), d1.batch(1), d1.batch(2)
    np.testing.assert_array_equal(d2.batch(2)[0], b2[0])
    # state_dict roundtrip
    d1.step = 5
    d3 = SyntheticLM(cfg)
    d3.load_state_dict(d1.state_dict())
    assert d3.step == 5


@pytest.mark.parametrize("world", [1, 2, 4, 8])
def test_data_elastic_resharding(world):
    """Any dp_world slices the SAME global batch."""
    cfg = DataConfig(vocab_size=777, seq_len=8, global_batch=8)
    full = SyntheticLM(cfg).batch(3)[0]
    rows = []
    for r in range(world):
        rows.append(SyntheticLM(cfg, dp_rank=r, dp_world=world).batch(3)[0])
    np.testing.assert_array_equal(np.concatenate(rows, 0), full)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=12, global_batch=2)
    toks, labels = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
