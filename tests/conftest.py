import os
import sys

# Tests must see exactly ONE device (the dry-run alone forces 512);
# make sure a leaked XLA_FLAGS can't change test semantics.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)

# Shared hypothesis budget: tier-1 must finish on CPU in minutes, so every
# property test runs few, deterministic examples (override with
# HYPOTHESIS_PROFILE=thorough for a deeper local sweep).  Modules guard the
# import and provide hand-picked fallback cases, so the suite collects and
# the oracle properties still run when hypothesis is not installed.
try:
    from hypothesis import HealthCheck, settings

    HAVE_HYPOTHESIS = True
    settings.register_profile(
        "tier1", max_examples=10, deadline=None, derandomize=True,
        suppress_health_check=list(HealthCheck))
    settings.register_profile("thorough", max_examples=100, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "tier1"))
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Drop compiled executables between test modules.

    A full-suite run accumulates several hundred jitted programs in one
    process; around the ~300th compilation the XLA CPU backend segfaults
    inside ``backend_compile`` (LLVM JIT state, not our code — the same
    test passes in isolation and in any smaller module subset).  Clearing
    the executable caches at module boundaries keeps the process under
    that threshold without changing per-module compile-count assertions.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_pool(forest, n_kv, d, key=0, dtype=None):
    """Random paged KV pool covering a forest (after assign_dense_pages)."""
    import jax.numpy as jnp
    from repro.core import plan as plan_mod
    pages = plan_mod.assign_dense_pages(forest)
    ps = forest.block_size
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    dt = dtype or jnp.float32
    k_pool = jax.random.normal(k1, (pages, ps, n_kv, d), dt)
    v_pool = jax.random.normal(k2, (pages, ps, n_kv, d), dt)
    return k_pool, v_pool


def dense_from_pool(forest, k_pool, v_pool):
    """Gather per-request dense (B, L, n_kv, d) KV from a paged pool."""
    import numpy as np
    ps = forest.block_size
    reqs = forest.request_ids
    lens = [forest.context_len(r) for r in reqs]
    L = max(lens)
    n_kv, d = k_pool.shape[2], k_pool.shape[3]
    kd = np.zeros((len(reqs), L, n_kv, d), np.float32)
    vd = np.zeros((len(reqs), L, n_kv, d), np.float32)
    for i, r in enumerate(reqs):
        pos = 0
        for node in forest.path(r):
            for j, pg in enumerate(node.page_ids):
                take = min(ps, node.length - j * ps)
                if take <= 0:
                    continue
                kd[i, pos:pos + take] = np.asarray(k_pool[pg])[:take]
                vd[i, pos:pos + take] = np.asarray(v_pool[pg])[:take]
                pos += take
    return kd, vd, np.asarray(lens, np.int32)
