"""Telemetry layer (``core/metrics.py`` + ``serving/telemetry.py``).

DESIGN.md §13 contracts:

* metrics primitives — monotone counters, histogram quantiles against
  exact percentiles, non-destructive snapshots + reader-owned deltas;
* engine wiring — counters stay monotone across a replay, two readers
  polling at different cadences see consistent (never double-counted)
  cache deltas, the eager flush wait lands in ``flush_time`` instead
  of polluting a later step's dispatch split;
* tracing — exported Chrome trace JSON is well-formed, per-request
  spans nest without partial overlap, every finished request closes
  with a terminal instant, and a fake clock makes the timestamps
  deterministic;
* non-perturbation — token streams are byte-identical with telemetry
  on vs off across eager / fused / cached / speculative modes.
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import metrics as M
from repro.models import transformer as T
from repro.serving.cache import CachePolicy
from repro.serving.engine import DecodeEngine
from repro.serving.speculation import SpecConfig
from repro.serving.telemetry import (METRIC_CATALOG, MemoryTraceSink,
                                     Telemetry)

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
PAGE = 8
DOC = list(range(10, 10 + 24))
PATTERN = [5, 7, 11, 13]
REP_PROMPT = (PATTERN * 6)[:24]


# --------------------------------------------------------------------- #
# metrics primitives
# --------------------------------------------------------------------- #
def test_counter_monotone_and_gauge():
    reg = M.MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    reg.gauge("g").set(7)
    assert reg["g"].value == 7.0
    with pytest.raises(TypeError):
        reg.gauge("c")            # kind clash


def test_histogram_quantiles_vs_exact():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-4.0, sigma=1.0, size=2000)
    h = M.Histogram("h")
    for s in samples:
        h.observe(float(s))
    assert h.count == len(samples)
    assert np.isclose(h.sum, samples.sum())
    for q in (0.1, 0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.quantile(q)
        # bucket growth is 1.25x: interpolation error bounded by one
        # bucket width
        assert exact / 1.25 <= est <= exact * 1.25, (q, exact, est)
    assert h.quantile(0.0) == pytest.approx(h.min)
    assert h.quantile(1.0) == pytest.approx(h.max)


def test_snapshot_delta_is_reader_owned():
    reg = M.MetricsRegistry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(2)
    h.observe(0.5)
    snap_a = reg.snapshot()       # reader A
    snap_b = reg.snapshot()       # reader B, same instant
    c.inc(3)
    h.observe(1.0)
    now = reg.snapshot()
    da = M.delta(now, snap_a)
    db = M.delta(now, snap_b)
    assert da["c"]["value"] == db["c"]["value"] == 3
    assert da["h"]["count"] == 1
    # snapshots are non-destructive: taking one changed nothing
    assert reg["c"].value == 5
    assert M.hist_quantile(da["h"], 0.5) > 0.5 / 1.25


def test_hist_quantile_empty_and_bounds():
    h = M.Histogram("h")
    assert M.hist_quantile(h.snapshot(), 0.5) == 0.0
    with pytest.raises(ValueError):
        M.hist_quantile(h.snapshot(), 1.5)


# --------------------------------------------------------------------- #
# engine wiring
# --------------------------------------------------------------------- #
def _engine(telemetry=None, **kw):
    kwargs = dict(page_size=PAGE, num_pages=256, backend="codec-xla",
                  max_q=8, temperature=0.0, telemetry=telemetry)
    kwargs.update(kw)
    return DecodeEngine(CFG, PARAMS, **kwargs)


def _streams(eng, prompts, max_new=6):
    rids = [eng.add_request(list(p), max_new=max_new) for p in prompts]
    eng.run(100)
    return {i: list(eng.requests[r].generated)
            for i, r in enumerate(rids)}


def test_counters_monotone_across_replay():
    tm = Telemetry()
    eng = _engine(telemetry=tm, cache=CachePolicy())
    prev = eng.publish_metrics().snapshot()
    for wave in range(3):
        prompts = [DOC + [100 + 10 * wave + i] for i in range(2)]
        for p in prompts:
            eng.add_request(p, max_new=4)
        while eng.has_work():
            eng.step()
            now = eng.publish_metrics().snapshot()
            for name, s in now.items():
                if s["type"] == "counter":
                    assert s["value"] >= prev[name]["value"], name
                elif s["type"] == "histogram":
                    assert s["count"] >= prev[name]["count"], name
            prev = now
        eng.flush_tokens()
        eng._stream_ready()
        for r in list(eng.requests):
            eng.release(r)
    snap = eng.publish_metrics().snapshot()
    assert snap["requests_done"]["value"] == 6
    assert snap["ttft_s"]["count"] == 6
    assert snap["cache_hits"]["value"] > 0


def test_two_cache_readers_never_double_count():
    """serve.py-style interval reader + serve_replay-style per-step
    reader must both see the true cache-hit total (the old rolling
    ``step_stats`` snapshot double-counted on the second read)."""
    tm = Telemetry()
    eng = _engine(telemetry=tm, cache=CachePolicy())
    interval_prev = eng.publish_metrics().snapshot()
    step_prev = eng.publish_metrics().snapshot()
    interval_total = step_total = 0.0
    for wave in range(3):
        for i in range(2):
            eng.add_request(DOC + [50 + 10 * wave + i], max_new=3)
        k = 0
        while eng.has_work():
            eng.step()
            now = eng.publish_metrics().snapshot()      # per-step reader
            step_total += M.delta(now, step_prev)["cache_hits"]["value"]
            step_prev = now
            k += 1
            if k % 2 == 0:                              # interval reader
                now = eng.publish_metrics().snapshot()
                interval_total += M.delta(
                    now, interval_prev)["cache_hits"]["value"]
                interval_prev = now
        eng.flush_tokens()
        eng._stream_ready()
        for r in list(eng.requests):
            eng.release(r)
    final = eng.publish_metrics().snapshot()["cache_hits"]["value"]
    tail = M.delta(eng.publish_metrics().snapshot(),
                   interval_prev)["cache_hits"]["value"]
    assert step_total == final
    assert interval_total + tail == final
    assert final == eng.cache.stats["hits"]
    # the per-step step_stats view agrees with the registry total
    assert sum(s.get("cache_hits", 0) for s in eng.step_stats) == final


def test_flush_time_attribution():
    """Deferred token syncs land in their own ``flush_time`` key, never
    in the dispatch/compute split of whichever step ran the flush."""
    tm = Telemetry()
    eng = _engine(telemetry=tm, fused=True)
    for i in range(2):
        eng.add_request(DOC + [100 + i], max_new=6)
    eng.run(100)
    rows = [s for s in eng.step_stats if "flush_time" in s]
    assert rows, "no step recorded a flush"
    assert all(s["flush_time"] >= 0 for s in rows)
    assert all(s.get("dispatch_time", 0) >= 0 for s in eng.step_stats)
    # every sync the engine performed is accounted under flush_time
    # (step rows for in-step flushes; boundary flushes accumulate on
    # the engine total), and the registry saw one observation per sync
    assert sum(s["flush_time"] for s in rows) \
        <= eng.stats["decode_sync_time"] + 1e-9
    snap = tm.metrics.snapshot()
    assert snap["flush_s"]["count"] == eng.stats["token_flushes"]
    assert snap["flush_s"]["sum"] == pytest.approx(
        eng.stats["decode_sync_time"])


def test_profile_every_splits_step():
    tm = Telemetry(profile_every=2)
    eng = _engine(telemetry=tm, fused=True)
    for i in range(2):
        eng.add_request(DOC + [100 + i], max_new=8)
    eng.run(100)
    profiled = [s for s in eng.step_stats if s.get("profiled")]
    assert profiled, "profile_every=2 sampled no steps"
    for s in profiled:
        assert s["dispatch_time"] >= 0
        assert s["compute_time"] >= 0
    snap = tm.metrics.snapshot()
    assert snap["profile_device_s"]["count"] == len(profiled)
    # unsampled fused steps stay async: no compute split recorded
    unsampled = [s for s in eng.step_stats
                 if s.get("dispatch_time", 0) and not s.get("profiled")]
    assert all("compute_time" not in s for s in unsampled)


# --------------------------------------------------------------------- #
# tracing
# --------------------------------------------------------------------- #
def _check_trace_shape(events):
    assert events, "no trace events"
    for ev in events:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
    spans = {}
    for ev in events:
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
            spans.setdefault((ev["pid"], ev["tid"]), []).append(
                (ev["ts"], ev["ts"] + ev["dur"], ev["name"]))
    for track, ss in spans.items():
        ss.sort()
        for (s0, e0, n0), (s1, e1, n1) in zip(ss, ss[1:]):
            assert not (s1 < e0 < e1), \
                f"{track}: {n1} partially overlaps {n0}"
    return spans


def test_trace_export_valid_chrome_json(tmp_path):
    tm = Telemetry()
    eng = _engine(telemetry=tm)
    _streams(eng, [DOC + [100], DOC + [101]], max_new=4)
    path = tmp_path / "trace.json"
    tm.export_trace(str(path))
    doc = json.loads(path.read_text())
    spans = _check_trace_shape(doc["traceEvents"])
    req_tracks = [t for (pid, t) in spans if pid == 2]
    assert len(req_tracks) == 2
    for (pid, tid), ss in spans.items():
        if pid != 2:
            continue
        names = [n for (_, _, n) in ss]
        assert "queued" in names and "prefill" in names \
            and "decode" in names
    # every request reached a terminal instant on its own track
    instants = {ev["tid"] for ev in doc["traceEvents"]
                if ev["ph"] == "i" and ev["pid"] == 2
                and ev["name"] == "done"}
    assert instants == set(req_tracks)


def test_fake_clock_trace_is_deterministic():
    def run():
        clock = lambda: float(clock.t)
        clock.t = 0.0
        tm = Telemetry(sink=MemoryTraceSink())
        eng = _engine(telemetry=tm, clock=clock)
        eng.add_request(DOC + [100], max_new=4)
        while eng.has_work():
            eng.step()
            clock.t += 1.0
        eng.flush_tokens()
        eng._stream_ready()
        eng._notify_done()
        return [(e["name"], e["ph"], e.get("ts"), e.get("dur"))
                for e in tm.trace_events()]

    a, b = run(), run()
    assert a == b
    # fake seconds, microsecond trace units: integral timestamps
    assert all(ts is None or ts == int(ts) for (_, _, ts, _) in a)


def test_queue_wait_on_fake_clock():
    clock = lambda: float(clock.t)
    clock.t = 0.0
    tm = Telemetry()
    # one slot: the second request must wait in the queue
    eng = _engine(telemetry=tm, clock=clock, max_running=1)
    eng.add_request(DOC + [100], max_new=3)
    eng.add_request(DOC + [101], max_new=3)
    while eng.has_work():
        eng.step()
        clock.t += 1.0
    eng.flush_tokens()
    eng._stream_ready()
    snap = tm.metrics.snapshot()
    assert snap["queue_wait_s"]["count"] == 2
    assert snap["queue_wait_s"]["min"] == 0.0    # first admitted at once
    assert snap["queue_wait_s"]["max"] >= 1.0    # second waited steps


# --------------------------------------------------------------------- #
# non-perturbation
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["eager", "fused", "cached", "spec"])
def test_streams_identical_with_telemetry_on_off(mode):
    kw = {}
    prompts = [DOC + [100], DOC + [101], DOC + [102]]
    if mode == "fused":
        kw["fused"] = True
    elif mode == "cached":
        kw["cache"] = CachePolicy()
    elif mode == "spec":
        kw["speculative"] = SpecConfig(depth=2, branch=2, max_nodes=3)
        prompts = [list(REP_PROMPT), REP_PROMPT + [9]]
    off = _streams(_engine(telemetry=None, **kw), prompts)
    on = _streams(_engine(telemetry=Telemetry(profile_every=3), **kw),
                  prompts)
    assert on == off
    assert all(off.values())


def test_metrics_export_schema(tmp_path):
    tm = Telemetry()
    eng = _engine(telemetry=tm)
    _streams(eng, [DOC + [100]], max_new=3)
    path = tmp_path / "metrics.json"
    eng.export_metrics(str(path), extra={"passes": {"cold": {}}})
    doc = json.loads(path.read_text())
    assert doc["schema"] == "codec-metrics/1"
    assert doc["passes"] == {"cold": {}}
    assert set(doc["metrics"]) >= set(METRIC_CATALOG)
    for name, (kind, _) in METRIC_CATALOG.items():
        assert doc["metrics"][name]["type"] == kind
