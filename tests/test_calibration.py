"""Cost-model calibration + sharded performance-model fixes (PR 7).

Regression coverage for the sharded-decode performance model:

* ``CostModel._interp`` on degenerate profiled tables (1-row / 1-column
  grids) — the old ``np.clip(searchsorted - 1, 0, -1)`` relied on
  numpy's undefined min>max clip plus negative-index wrapping;
* packed merge-cost accounting: one launch + one wire move per
  butterfly round, matching ``por_subgroup_merge``;
* ``CostModel.fit`` recovering planted hardware coefficients from
  synthetic step timings (and leaving non-varying columns alone);
* ``replicate_gain`` preferring replication for hot short prefixes and
  sequence splitting for long documents;
* the sharded scheduler charging the ICI merge exactly once (the old
  per-piece surcharge double-counted it);
* ``ShardedPageAllocator`` affinity entries of LIVE nodes surviving the
  size bound (the old FIFO pop reset their ``seq_split_pages`` quota).
"""

import numpy as np
import pytest

from repro.core.cost_model import CostModel, HardwareSpec
from repro.core.scheduler import TaskSpec, divide_and_schedule_sharded
from repro.distributed.kv_pool import ShardedPageAllocator


# --------------------------------------------------------------------- #
# _interp on degenerate profiled grids
# --------------------------------------------------------------------- #
class _NoNegativeIndex(np.ndarray):
    """ndarray that rejects negative integer indices — catches clamp
    logic that only 'works' through Python's index wrapping."""

    def __getitem__(self, idx):
        for k in (idx if isinstance(idx, tuple) else (idx,)):
            if isinstance(k, (int, np.integer)) and k < 0:
                raise AssertionError(
                    f"negative index {k!r} into the interpolation grid")
        return super().__getitem__(idx)


def _guard(cm: CostModel) -> CostModel:
    lnq, ln, vals = cm._grid
    cm._grid = (lnq, ln, vals.view(_NoNegativeIndex))
    return cm


def test_interp_single_cell_table():
    cm = _guard(CostModel(8, 2, 64, page_size=8, table={(4, 512): 3e-3}))
    # 1x1 grid: every query degrades to the single measured value
    for nq, n in ((1, 64), (4, 512), (64, 65536)):
        assert cm(nq, n) == pytest.approx(3e-3)


def test_interp_single_row_and_column_tables():
    # one n_q value, two n values: pure 1-D interpolation along n
    cm = _guard(CostModel(8, 2, 64, page_size=8,
                          table={(4, 512): 1e-3, (4, 2048): 2e-3}))
    assert cm(4, 512) == pytest.approx(1e-3)
    assert cm(4, 2048) == pytest.approx(2e-3)
    assert cm(4, 1024) == pytest.approx(1.5e-3)     # log2 midpoint
    assert cm(1, 512) == pytest.approx(1e-3)        # clamped in n_q
    assert cm(64, 4096) == pytest.approx(2e-3)      # clamped in n
    # one n value, two n_q values: 1-D along n_q
    cm = _guard(CostModel(8, 2, 64, page_size=8,
                          table={(2, 512): 1e-3, (8, 512): 3e-3}))
    assert cm(4, 512) == pytest.approx(2e-3)        # log2 midpoint
    assert cm(16, 64) == pytest.approx(3e-3)        # clamped both ways


def test_interp_full_grid_never_indexes_negative():
    table = {(nq, n): 1e-4 * nq * n / 512
             for nq in (1, 4, 16) for n in (512, 2048)}
    cm = _guard(CostModel(8, 2, 64, page_size=8, table=table))
    # corners, interior, and far outside the grid on both axes
    for nq in (1, 2, 3, 16, 128):
        for n in (1, 512, 1000, 2048, 1 << 20):
            assert np.isfinite(cm(nq, n)) and cm(nq, n) > 0


# --------------------------------------------------------------------- #
# packed merge accounting: one launch + one transfer per round
# --------------------------------------------------------------------- #
def test_merge_cost_single_launch_per_round():
    hw = HardwareSpec(ici_bw=50e9, launch_overhead=5e-6)
    cm = CostModel(8, 2, 64, page_size=8, hw=hw)
    wire = 16 * 8 * (64 + 2) * 4        # packed (o, m, l) f32 buffer
    for splits, rounds in ((2, 1), (4, 2), (8, 3)):
        expect = rounds * (wire / hw.ici_bw + hw.launch_overhead)
        assert cm.merge_cost(splits, 16) == pytest.approx(expect)
    # the launch term is per ROUND, not per ppermute: tripling the
    # launch overhead must shift the cost by exactly rounds * 2 * ovh
    hw3 = HardwareSpec(ici_bw=50e9, launch_overhead=15e-6)
    cm3 = CostModel(8, 2, 64, page_size=8, hw=hw3)
    assert (cm3.merge_cost(4, 16) - cm.merge_cost(4, 16)
            == pytest.approx(2 * 2 * 5e-6))


def test_replicate_gain_prefers_hot_short_nodes():
    cm = CostModel(8, 2, 64, page_size=16)
    # hot short prefix: merge wire dwarfs the duplicated read
    assert cm.replicate_gain(8, 64, 4) > 0
    # long document: the parallel read win dominates
    assert cm.replicate_gain(2, 65536, 4) < 0
    assert cm.replicate_gain(8, 64, 1) == 0.0


# --------------------------------------------------------------------- #
# fit(): measured-cost calibration from step features
# --------------------------------------------------------------------- #
def _samples(hw: HardwareSpec, rng) -> list:
    rows = []
    for _ in range(48):
        hbm = float(rng.uniform(1e6, 5e8))
        steps = float(rng.integers(4, 400))
        mb = float(rng.uniform(0, 2e6))
        mr = float(rng.integers(0, 12))
        secs = (hbm / hw.hbm_bw + steps * hw.grid_step_overhead
                + mb / hw.ici_bw + mr * hw.launch_overhead)
        rows.append(dict(hbm_bytes=hbm, grid_steps=steps, merge_bytes=mb,
                         merge_rounds=mr, seconds=secs))
    return rows


def test_fit_recovers_planted_coefficients():
    true = HardwareSpec(hbm_bw=123e9, ici_bw=7e9,
                        grid_step_overhead=3e-6, launch_overhead=11e-6)
    cm = CostModel(8, 2, 64, page_size=16)
    assert not cm.calibrated
    assert cm.fit(_samples(true, np.random.default_rng(0)))
    assert cm.calibrated
    assert cm.hw.hbm_bw == pytest.approx(true.hbm_bw, rel=1e-3)
    assert cm.hw.ici_bw == pytest.approx(true.ici_bw, rel=1e-3)
    assert cm.hw.grid_step_overhead == pytest.approx(
        true.grid_step_overhead, rel=1e-3)
    assert cm.hw.launch_overhead == pytest.approx(
        true.launch_overhead, rel=1e-3)


def test_fit_keeps_coefficients_without_variation():
    # every step identical in the merge columns -> ici/launch untouched
    true = HardwareSpec(hbm_bw=200e9)
    rng = np.random.default_rng(1)
    rows = []
    for _ in range(32):
        hbm = float(rng.uniform(1e7, 4e8))
        rows.append(dict(hbm_bytes=hbm, grid_steps=0.0, merge_bytes=0.0,
                         merge_rounds=0.0, seconds=hbm / true.hbm_bw))
    cm = CostModel(8, 2, 64, page_size=16)
    before = cm.hw
    assert cm.fit(rows)
    assert cm.hw.hbm_bw == pytest.approx(true.hbm_bw, rel=1e-3)
    assert cm.hw.ici_bw == before.ici_bw
    assert cm.hw.launch_overhead == before.launch_overhead
    assert cm.hw.grid_step_overhead == before.grid_step_overhead


def test_fit_requires_enough_samples():
    cm = CostModel(8, 2, 64, page_size=16)
    rows = _samples(HardwareSpec(), np.random.default_rng(2))[:5]
    assert not cm.fit(rows)
    assert not cm.calibrated


# --------------------------------------------------------------------- #
# sharded scheduler: the merge is charged ONCE, not per piece
# --------------------------------------------------------------------- #
def test_sharded_merge_charged_once_and_row_accurate():
    cm = CostModel(4, 2, 16, page_size=8)
    # one 8-page node whose pages straddle 2 shards (stride 16)
    pages = {1: list(range(4)) + list(range(16, 20))}
    tasks = [TaskSpec(1, 4, 64)]
    sched = divide_and_schedule_sharded(
        tasks, cm, 2, 2, 8, node_pages=lambda nid: pages[nid],
        shard_of_page=lambda g: g // 16, num_queries=4)
    assert sched.seq_splits == 1
    # merge term == the model's single charge for the full batch...
    assert sched.merge_cost == pytest.approx(cm.merge_cost(2, 4))
    assert sched.makespan == pytest.approx(
        max(max(s.lane_costs) for s in sched.shards) + sched.merge_cost)
    # ...and shrinks with the merge-row count when rows skip the wire
    sparse = divide_and_schedule_sharded(
        tasks, cm, 2, 2, 8, node_pages=lambda nid: pages[nid],
        shard_of_page=lambda g: g // 16, num_queries=4,
        num_merge_queries=1)
    assert sparse.merge_cost == pytest.approx(cm.merge_cost(2, 1))
    assert sparse.merge_cost < sched.merge_cost
    # pieces carry only local compute: the per-shard lane costs must not
    # exceed the whole node's undivided cost (the old surcharge added
    # the full merge to every piece, inflating lanes past this bound)
    whole = cm(4, 64)
    for s in sched.shards:
        assert max(s.lane_costs) <= whole + 1e-12


def test_sharded_replicated_prefix_identical_across_shards():
    cm = CostModel(4, 2, 16, page_size=8)
    pages = {1: list(range(4)), 2: list(range(16, 18))}
    tasks = [TaskSpec(1, 4, 32), TaskSpec(2, 4, 16)]
    sched = divide_and_schedule_sharded(
        tasks, cm, 2, 2, 8, node_pages=lambda nid: pages[nid],
        shard_of_page=lambda g: g // 16, num_queries=4,
        replicated={1}, num_merge_queries=0)
    assert sched.merge_cost == 0.0
    # node 1's subtasks are prepended IDENTICALLY to every shard
    reps = [[(s.node_id, s.q_lo, s.q_hi, s.kv_lo, s.kv_hi)
             for s in sh.subtasks if s.node_id == 1]
            for sh in sched.shards]
    assert reps[0] and reps[0] == reps[1]
    prefix = [[s.node_id for s in sh.subtasks[:len(reps[0])]]
              for sh in sched.shards]
    assert all(set(p) == {1} for p in prefix)
    # node 2 stays local to its shard
    locs = [[s for s in sh.subtasks if s.node_id == 2]
            for sh in sched.shards]
    assert bool(locs[0]) != bool(locs[1])


# --------------------------------------------------------------------- #
# affinity size bound must not evict live nodes (quota reset bug)
# --------------------------------------------------------------------- #
def test_affinity_eviction_keeps_live_quota():
    al = ShardedPageAllocator(2, 64, seq_split_pages=4)
    live = al.alloc(2, hint=1)              # 2/4 of the quota used
    s0 = al.shard_of(live[0])
    # churn far more dead hints than the size bound holds
    for h in range(10_000):
        al.release(al.alloc(1, hint=1000 + h))
    # the LIVE entry survived: growth continues the same run...
    more = al.alloc(2, hint=1)
    assert [al.shard_of(g) for g in more] == [s0, s0]
    # ...and the quota kept counting — the 5th page must move shards
    # (a reset quota would keep it on s0 and scatter later growth)
    nxt = al.alloc(1, hint=1)
    assert al.shard_of(nxt[0]) == 1 - s0
    al.check()


def test_affinity_release_reaps_dead_hints():
    al = ShardedPageAllocator(2, 8, seq_split_pages=2)
    rows = al.alloc(2, hint=5)
    assert al._affinity[5][2] == 2          # live refcount tracks rows
    al.release(rows)
    assert al._affinity[5][2] == 0          # dead -> evictable
    for h in range(9_000):
        al.release(al.alloc(1, hint=100_000 + h))
    assert 5 not in al._affinity            # bound reclaimed it
    assert len(al._affinity) <= 8192
    al.check()


def test_alloc_replicas_all_or_nothing():
    al = ShardedPageAllocator(2, 8)
    taken = al.alloc(5)                     # one shard now has < 4 free
    reps = al.alloc_replicas(3, hint=9)
    assert set(reps) == {0, 1}
    assert all(len(v) == 3 for v in reps.values())
    assert all(al.shard_of(g) == s for s, v in reps.items() for g in v)
    free_before = al.num_free
    with pytest.raises(MemoryError):
        al.alloc_replicas(4, hint=10)       # shard with 5 taken can't fit
    assert al.num_free == free_before       # nothing leaked on failure
    for v in reps.values():
        al.release(v)
    al.release(taken)
    assert al.num_free == al.num_pages
    al.check()
