"""End-to-end shared-prefix attention: every impl vs the dense oracle,
over deterministic hand-picked forests plus (when hypothesis is
installed) randomly generated ones — the system-level property test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, dense_from_pool, make_pool
from repro.core import cost_model, plan as plan_mod, tree as tree_mod
from repro.kernels import ops, ref

PAGE = 16
CM = cost_model.CostModel(4, 2, 16, page_size=PAGE)


# --------------------------------------------------------------------- #
# oracle checks (shared by the deterministic and property-based tests)
# --------------------------------------------------------------------- #
def _check_matches_dense_oracle(f, impl):
    f.validate()
    B = len(f.request_ids)
    k_pool, v_pool = make_pool(f, 2, 16)
    p = plan_mod.build_plan(f, CM, num_lanes=2, max_q=8,
                            max_kv_per_task=2 * PAGE)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, 4, 16))
    out = ops.codec_attention(q, k_pool, v_pool, p, impl=impl)
    kd, vd, lens = dense_from_pool(f, k_pool, v_pool)
    expect = ref.decode_attention_ref(q, jnp.asarray(kd), jnp.asarray(vd),
                                      jnp.asarray(lens))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def _check_pallas_matches_xla(f):
    B = len(f.request_ids)
    k_pool, v_pool = make_pool(f, 2, 16)
    p = plan_mod.build_plan(f, CM, num_lanes=2, max_q=8)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 4, 16))
    o_x = ops.codec_attention(q, k_pool, v_pool, p, impl="xla")
    o_p = ops.codec_attention(q, k_pool, v_pool, p, impl="pallas")
    np.testing.assert_allclose(o_p, o_x, rtol=1e-5, atol=1e-5)


def _check_segment_reduction_equals_pairwise_por(n_parts, seed):
    """The flattened segment LSE == any order of pairwise POR merges
    (associativity/commutativity, paper §4.3)."""
    h, d, nq = 2, 8, 3
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3 * n_parts)
    parts = []
    for i in range(n_parts):
        o = jax.random.normal(ks[3 * i], (nq, h, d))
        m = jax.random.normal(ks[3 * i + 1], (nq, h)) * 2
        l = jnp.abs(jax.random.normal(ks[3 * i + 2], (nq, h))) + 0.1
        parts.append((o, m, l))
    # pairwise left fold
    o, m, l = parts[0]
    for o2, m2, l2 in parts[1:]:
        o, m, l = ref.por_ref(o, m, l, o2, m2, l2)
    # pairwise reversed fold
    o_r, m_r, l_r = parts[-1]
    for o2, m2, l2 in reversed(parts[:-1]):
        o_r, m_r, l_r = ref.por_ref(o_r, m_r, l_r, o2, m2, l2)
    np.testing.assert_allclose(o, o_r, rtol=1e-5, atol=1e-5)
    # segment reduction over all parts at once
    o_parts = jnp.concatenate([p[0] for p in parts], 0)
    m_parts = jnp.concatenate([p[1] for p in parts], 0)
    l_parts = jnp.concatenate([p[2] for p in parts], 0)
    segs = jnp.tile(jnp.arange(nq), n_parts)
    o_seg = ref.combine_partials_ref(o_parts, m_parts, l_parts, segs, nq)
    np.testing.assert_allclose(o_seg, o, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# deterministic hand-picked forests (run with or without hypothesis)
# --------------------------------------------------------------------- #
def _mixed_forest():
    """Two unrelated roots, uneven depths, a partial tail page."""
    f = tree_mod.PrefixForest(PAGE)
    r1 = f._new_node(tree_mod.ROOT_ID, 2 * PAGE, 0)
    mid = f._new_node(r1.id, PAGE, r1.end_pos)
    f.attach_request(0, f._new_node(mid.id, PAGE + 5, mid.end_pos).id)
    f.attach_request(1, f._new_node(mid.id, 3, mid.end_pos).id)
    f.attach_request(2, f._new_node(r1.id, 2 * PAGE, r1.end_pos).id)
    r2 = f._new_node(tree_mod.ROOT_ID, PAGE, 0)
    f.attach_request(3, f._new_node(r2.id, 2 * PAGE - 1, r2.end_pos).id)
    return f


def _named_forests():
    return {
        "two_level": tree_mod.two_level(4, 3 * PAGE, PAGE + 3, PAGE),
        "kary": tree_mod.full_kary(3, 2, 2 * PAGE, PAGE),
        "degenerate": tree_mod.degenerate(4, 2 * PAGE, PAGE),
        "single_request": tree_mod.two_level(1, 2 * PAGE, 5, PAGE),
        "mixed": _mixed_forest(),
    }


@pytest.mark.parametrize("name", sorted(_named_forests()))
@pytest.mark.parametrize("impl", ["xla", "ref"])
def test_codec_matches_dense_oracle_fixed(name, impl):
    _check_matches_dense_oracle(_named_forests()[name], impl)


@pytest.mark.parametrize("name", ["two_level", "mixed"])
def test_pallas_impl_matches_xla_fixed(name):
    _check_pallas_matches_xla(_named_forests()[name])


@pytest.mark.parametrize("n_parts,seed", [(1, 0), (2, 1), (5, 2)])
def test_segment_reduction_equals_pairwise_por_fixed(n_parts, seed):
    _check_segment_reduction_equals_pairwise_por(n_parts, seed)


# --------------------------------------------------------------------- #
# property-based sweeps (hypothesis only; budget set in conftest)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def forests(draw):
        """Random forest: a few roots, random chains, random sharing."""
        f = tree_mod.PrefixForest(PAGE)
        n_roots = draw(st.integers(1, 3))
        rid = 0
        for _ in range(n_roots):
            root_len = draw(st.integers(1, 4)) * PAGE
            root = f._new_node(tree_mod.ROOT_ID, root_len, 0)
            n_children = draw(st.integers(1, 3))
            for _ in range(n_children):
                depth = draw(st.integers(0, 2))
                cur = root
                for _ in range(depth):
                    cur = f._new_node(cur.id,
                                      draw(st.integers(1, 2)) * PAGE,
                                      cur.end_pos)
                leaf = f._new_node(cur.id, draw(st.integers(1, 2 * PAGE)),
                                   cur.end_pos)
                f.attach_request(rid, leaf.id)
                rid += 1
        return f

    @given(forests(), st.sampled_from(["xla", "ref"]))
    def test_codec_matches_dense_oracle(f, impl):
        _check_matches_dense_oracle(f, impl)

    @given(forests())
    @settings(max_examples=4)
    def test_pallas_impl_matches_xla(f):
        _check_pallas_matches_xla(f)

    @given(st.integers(1, 6), st.integers(0, 3))
    def test_segment_reduction_equals_pairwise_por(n_parts, seed):
        _check_segment_reduction_equals_pairwise_por(n_parts, seed)


# --------------------------------------------------------------------- #
# plan-structure regressions (hypothesis-free)
# --------------------------------------------------------------------- #
def test_flash_plan_is_prefix_blind_but_correct():
    """The FlashDecoding-style plan reads shared KV once per request —
    more IO, identical numerics."""
    f = tree_mod.two_level(4, 4 * PAGE, PAGE, PAGE)
    k_pool, v_pool = make_pool(f, 2, 16)
    pc = plan_mod.build_plan(f, CM, num_lanes=2, max_q=8)
    pf = plan_mod.flash_plan(f, CM, num_lanes=2, max_q=8)
    # flash plan: every task single-query
    assert int(pf.task_qnum[:pf.num_tasks].max()) == 1
    # flash plan reads more pages in total
    assert pf.step_valid.sum() > pc.step_valid.sum()
    q = jax.random.normal(jax.random.PRNGKey(3), (4, 4, 16))
    o_c = ops.codec_attention(q, k_pool, v_pool, pc, impl="xla")
    o_f = ops.codec_attention(q, k_pool, v_pool, pf, impl="xla")
    np.testing.assert_allclose(o_c, o_f, rtol=1e-5, atol=1e-5)


def test_pad_plan_is_numerically_invisible():
    f = tree_mod.two_level(3, 2 * PAGE, PAGE, PAGE)
    k_pool, v_pool = make_pool(f, 2, 16)
    p = plan_mod.build_plan(f, CM, num_lanes=2, max_q=8)
    pp = plan_mod.pad_plan(p, steps=p.max_steps + 5,
                           tasks=p.task_qnum.shape[0] + 3)
    q = jax.random.normal(jax.random.PRNGKey(4), (3, 4, 16))
    o1 = ops.codec_attention(q, k_pool, v_pool, p, impl="xla")
    o2 = ops.codec_attention(q, k_pool, v_pool, pp, impl="xla")
    o3 = ops.codec_attention(q, k_pool, v_pool, pp, impl="pallas")
    np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(o1, o3, rtol=1e-5, atol=1e-5)
