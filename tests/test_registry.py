"""Backend registry contract + every registered backend vs the oracle.

The shared fixture is a doc-QA style forest; each registered backend
must match the dense decode-attention oracle within fp32 tolerance on
it, including GQA and sliding-window configs and a degenerate
single-request forest.  Plan edge cases (pad_plan bucketing,
window-pruning relane, trash-row flush) are covered at the bottom."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dense_from_pool, make_pool
from repro.core import cost_model, plan as plan_mod, tree as tree_mod
from repro.kernels import hydragen, ops, ref, registry

PAGE = 16
BACKENDS = registry.names()


def _fixture(forest, hq=4, hkv=2, d=16, key=0):
    cm = cost_model.CostModel(hq, hkv, d, page_size=PAGE)
    k_pool, v_pool = make_pool(forest, hkv, d, key=key)
    B = len(forest.request_ids)
    q = jax.random.normal(jax.random.PRNGKey(key + 1), (B, hq, d))
    return cm, k_pool, v_pool, q


def _dense_expect(forest, q, k_pool, v_pool, window=0):
    kd, vd, lens = dense_from_pool(forest, k_pool, v_pool)
    return ref.decode_attention_ref(q, jnp.asarray(kd), jnp.asarray(vd),
                                    jnp.asarray(lens), window=window)


# --------------------------------------------------------------------- #
# registry API
# --------------------------------------------------------------------- #
def test_registry_has_all_required_backends():
    for name in ("codec-pallas", "codec-xla", "flash", "hydragen", "ref"):
        be = registry.get(name)
        assert be.name == name
        assert be.needs_plan
        assert be.supports_gqa
    assert registry.get("flash").plan_kind == "flash"
    assert registry.get("hydragen").plan_kind == "codec"


def test_registry_unknown_name_lists_known():
    with pytest.raises(KeyError, match="hydragen"):
        registry.get("nonexistent-backend")


def test_registry_rejects_duplicate_registration():
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.get("hydragen"))


def test_registry_capability_filter():
    assert set(registry.names(window=True)) == set(BACKENDS)
    assert registry.names(gqa=True) == registry.names()


# --------------------------------------------------------------------- #
# every backend vs the dense oracle on the shared forest fixture
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_dense_oracle_shared_forest(backend):
    f = tree_mod.two_level(4, 4 * PAGE, PAGE + 5, PAGE)
    cm, k_pool, v_pool, q = _fixture(f)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8,
                            max_kv_per_task=2 * PAGE)
    out = registry.get(backend)(q, k_pool, v_pool, p)
    np.testing.assert_allclose(out, _dense_expect(f, q, k_pool, v_pool),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("hq,hkv", [(8, 2), (6, 1)])
def test_backend_matches_oracle_gqa(backend, hq, hkv):
    f = tree_mod.full_kary(3, 2, 2 * PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, hq=hq, hkv=hkv, key=3)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8)
    out = registry.get(backend)(q, k_pool, v_pool, p)
    np.testing.assert_allclose(out, _dense_expect(f, q, k_pool, v_pool),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_oracle_sliding_window(backend):
    win = 24
    f = tree_mod.two_level(3, 4 * PAGE, 2 * PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, key=5)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4, window=win)
    out = registry.get(backend)(q, k_pool, v_pool, p, window=win)
    np.testing.assert_allclose(
        out, _dense_expect(f, q, k_pool, v_pool, window=win),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_oracle_single_request(backend):
    """Degenerate forest: one request, no sharing at all."""
    f = tree_mod.two_level(1, 2 * PAGE, 7, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, key=7)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4)
    out = registry.get(backend)(q, k_pool, v_pool, p)
    np.testing.assert_allclose(out, _dense_expect(f, q, k_pool, v_pool),
                               rtol=1e-4, atol=1e-4)


def test_backend_partials_por_merge_with_tail():
    """A backend's partials must be POR-mergeable: plan over a KV prefix
    merged with dense attention over the rest == full attention (the
    engine's frozen-plan + tail-page decomposition)."""
    f = tree_mod.two_level(3, 2 * PAGE, 2 * PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, key=11)
    # truncate each leaf's last page out of the plan (the "tail")
    truncate = {}
    tails = []
    for r in f.request_ids:
        leaf = f.nodes[f.leaf_of[r]]
        ts = ((leaf.length - 1) // PAGE) * PAGE
        truncate[leaf.id] = ts
        tails.append((leaf, ts))
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8, truncate=truncate)
    expect = _dense_expect(f, q, k_pool, v_pool)
    for backend in BACKENDS:
        be = registry.get(backend)
        o_f, m_f, l_f = be.partials(q, k_pool, v_pool, p)
        tp = np.asarray([leaf.page_ids[ts // PAGE] for leaf, ts in tails])
        tb = jnp.asarray([leaf.start_pos + ts for leaf, ts in tails])
        qp = jnp.asarray([f.context_len(r) - 1 for r in f.request_ids])
        o_t, m_t, l_t = ops.single_page_attention(
            q, k_pool[jnp.asarray(tp)], v_pool[jnp.asarray(tp)], tb, qp)
        o, _, _ = ref.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
        np.testing.assert_allclose(o, expect, rtol=1e-4, atol=1e-4,
                                   err_msg=backend)


# --------------------------------------------------------------------- #
# hydragen decomposition internals
# --------------------------------------------------------------------- #
def test_hydragen_prepare_splits_by_sharing_degree():
    f = tree_mod.two_level(4, 4 * PAGE, PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8)
    ha = hydragen.prepare(p)
    S, U = ha.px_pages.shape[0], ha.sf_pages.shape[0]
    assert S + U == p.num_tasks
    assert S >= 1        # the shared doc node
    assert U == 4        # one private tail per request
    assert bool((ha.px_qnum > 1).all())
    # suffix segment ids are exactly the four query rows
    assert sorted(np.asarray(ha.sf_seg).tolist()) == [0, 1, 2, 3]


def test_hydragen_identical_prompts_prefix_only():
    """All requests share everything: leaf tails are empty, the whole
    batch is served by the prefix phase alone."""
    f = tree_mod.PrefixForest(PAGE)
    shared = f._new_node(tree_mod.ROOT_ID, 3 * PAGE, 0)
    for r in range(3):
        f.attach_request(r, f._new_node(shared.id, 0, shared.end_pos).id)
    cm, k_pool, v_pool, q = _fixture(f, key=13)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8)
    ha = hydragen.prepare(p)
    assert ha.sf_pages.shape[0] == 0
    out = registry.get("hydragen")(q, k_pool, v_pool, p)
    np.testing.assert_allclose(out, _dense_expect(f, q, k_pool, v_pool),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------- #
# plan edge cases
# --------------------------------------------------------------------- #
def test_pad_plan_bucketing_rounds_to_pow2_and_is_invisible():
    f = tree_mod.two_level(3, 3 * PAGE, PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, key=17)
    p = plan_mod.build_plan(f, cm, num_lanes=2, max_q=8)
    pp = plan_mod.pad_plan(p)
    # default bucketing: steps rounded up to the next power of two
    assert pp.max_steps == 1 << (p.max_steps - 1).bit_length()
    assert pp.step_valid[:, p.max_steps:].sum() == 0
    with pytest.raises(ValueError):
        plan_mod.pad_plan(p, steps=p.max_steps - 1)
    for backend in BACKENDS:
        o1 = registry.get(backend)(q, k_pool, v_pool, p)
        o2 = registry.get(backend)(q, k_pool, v_pool, pp)
        np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5,
                                   err_msg=backend)


def test_window_pruning_drops_pages_and_relanes():
    """A deep chain under a small window: wholly-invisible pages must be
    pruned from the plan, lanes rebalanced, numerics unchanged."""
    win = PAGE  # only the last page of each 6-page context is visible
    f = tree_mod.two_level(3, 4 * PAGE, 2 * PAGE, PAGE)
    cm, k_pool, v_pool, q = _fixture(f, key=19)
    p_full = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4)
    p_win = plan_mod.build_plan(f, cm, num_lanes=2, max_q=4, window=win)
    assert p_win.step_valid.sum() < p_full.step_valid.sum()
    # relane: every surviving subtask still has exactly one lane and the
    # step arrays cover exactly the surviving pages
    assert p_win.num_tasks < p_full.num_tasks or \
        p_win.step_valid.sum() < p_full.step_valid.sum()
    for backend in BACKENDS:
        out = registry.get(backend)(q, k_pool, v_pool, p_win, window=win)
        np.testing.assert_allclose(
            out, _dense_expect(f, q, k_pool, v_pool, window=win),
            rtol=1e-4, atol=1e-4, err_msg=backend)


def test_trash_row_flush_semantics():
    """Step padding flushes must land in the trash row (or rewrite a
    lane's final content) and never corrupt a live query — even with
    heavily imbalanced lanes."""
    # one giant node on one lane, tiny nodes elsewhere -> lots of padding
    f = tree_mod.PrefixForest(PAGE)
    big = f._new_node(tree_mod.ROOT_ID, 8 * PAGE, 0)
    f.attach_request(0, f._new_node(big.id, 3, big.end_pos).id)
    small = f._new_node(tree_mod.ROOT_ID, PAGE, 0)
    f.attach_request(1, f._new_node(small.id, 2, small.end_pos).id)
    cm, k_pool, v_pool, q = _fixture(f, key=23)
    p = plan_mod.build_plan(f, cm, num_lanes=4, max_q=4,
                            max_kv_per_task=None)
    # lanes are imbalanced: some lane has padding steps
    assert (p.step_valid.sum(1) < p.max_steps).any()
    # padded steps reference the lane's last task or the trash row
    trash = p.num_tasks
    for lane in range(p.num_lanes):
        pad = np.nonzero(p.step_valid[lane] == 0)[0]
        for s in pad:
            assert p.step_task[lane, s] == (p.step_task[lane, s - 1]
                                            if s > 0 else trash)
    # and numerics are exact for every backend incl. the pallas kernel
    # whose padding steps physically re-flush output rows
    for backend in BACKENDS:
        out = registry.get(backend)(q, k_pool, v_pool, p)
        assert bool(jnp.isfinite(out).all()), backend
        np.testing.assert_allclose(out,
                                   _dense_expect(f, q, k_pool, v_pool),
                                   rtol=1e-4, atol=1e-4, err_msg=backend)
