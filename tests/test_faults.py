"""Fault-tolerant serving (DESIGN.md §12): lifecycle control, fault
injection, graceful degradation, invariant self-checks.

The contracts under test:

* request lifecycle — ``cancel()``, per-request deadlines and queue
  timeouts (on an injectable clock) reach clean terminal states with
  their KV released and their ``on_done`` stream-close fired once;
* fault seams — seeded alloc/dispatch/NaN/callback/stall schedules
  are absorbed with surviving streams byte-identical to a fault-free
  run (the engine's core robustness claim);
* isolation — a raising user callback fails only its own request;
* ``engine.check()`` — planted state corruption is detected;
* validation — malformed requests are rejected at ``add_request``.
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.scheduler import AdmissionController
from repro.models import transformer as T
from repro.serving import sampler
from repro.serving.engine import (CANCELLED, DONE, FAILED, TIMED_OUT,
                                  DecodeEngine)
from repro.serving.faults import (KINDS, EngineInvariantError,
                                  FaultInjector, FaultPlan, FaultSpec)

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
DOC = list(range(10, 42))                 # 32 in-vocab tokens


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _engine(**kw):
    defaults = dict(page_size=16, num_pages=128, backend="codec-xla",
                    max_q=8, temperature=0.0)
    defaults.update(kw)
    return DecodeEngine(CFG, PARAMS, **defaults)


def _prompts(n=3):
    return [DOC + [100 + 5 * i + j for j in range(3)] for i in range(n)]


# --------------------------------------------------------------------- #
# fault plan / injector units
# --------------------------------------------------------------------- #
def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse("dispatch@3*2, nan_logits@5:1, stall@8=0.01")
    by_kind = {s.kind: s for s in plan}
    assert by_kind["dispatch"].times == 2
    assert by_kind["nan_logits"].rid == 1
    assert by_kind["stall"].payload == 0.01
    assert len(FaultPlan.parse("")) == 0
    seeded = FaultPlan.parse("seed:7:0.5")
    assert len(seeded) > 0
    # seeded schedules are reproducible byte-for-byte
    assert seeded.specs == FaultPlan.seeded(7, rate=0.5).specs
    with pytest.raises(ValueError):
        FaultPlan.parse("dispatch3")           # missing @step
    with pytest.raises(ValueError):
        FaultPlan([FaultSpec("frobnicate", 0)])


def test_injector_take_requeue_times():
    plan = FaultPlan([FaultSpec("dispatch", 2, times=2),
                      FaultSpec("nan_logits", 1, rid=5)])
    inj = FaultInjector(plan)
    inj.tick(0)
    assert inj.take("dispatch") is None         # not due yet
    inj.tick(2)
    assert inj.take("dispatch").times == 2      # fires twice
    assert inj.take("dispatch") is not None
    assert inj.take("dispatch") is None         # exhausted
    assert inj.take("nan_logits", rid=3) is None   # targeted elsewhere
    spec = inj.take("nan_logits", rid=5)
    assert spec is not None
    inj.requeue(spec)                           # seam couldn't apply
    assert inj.pending() == 1
    assert inj.take("nan_logits", rid=5) is spec
    assert inj.pending() == 0
    assert inj.total_fired == 3
    assert inj.fired == {**{k: 0 for k in KINDS},
                         "dispatch": 2, "nan_logits": 1}


def test_edf_admission_order():
    from repro.core.cost_model import CostModel
    from repro.core.scheduler import AdmissionPolicy
    ac = AdmissionController(AdmissionPolicy(),
                             CostModel(CFG.num_heads, CFG.num_kv_heads,
                                       CFG.head_dim, page_size=16), 16)
    ac.push(0)                    # no deadline -> back of the queue
    ac.push(1, deadline=9.0)
    ac.push(2, deadline=3.0)      # earliest deadline first
    ac.push(3, deadline=9.0)      # FIFO among equal deadlines
    assert list(ac.queue) == [2, 1, 3, 0]
    ac.remove(1)
    ac.remove(1)                  # tolerant of absence
    assert list(ac.queue) == [2, 3, 0]
    assert ac.pop() == 2


# --------------------------------------------------------------------- #
# input validation
# --------------------------------------------------------------------- #
def test_add_request_rejects_malformed():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.add_request([], max_new=4)                  # empty prompt
    with pytest.raises(ValueError):
        eng.add_request([1, 2], max_new=0)              # nothing to do
    with pytest.raises(ValueError):
        eng.add_request([1, CFG.vocab_size], max_new=4)  # out of vocab
    with pytest.raises(ValueError):
        eng.add_request([1, -3], max_new=4)
    with pytest.raises(ValueError):
        eng.add_request([1.5, 2.5], max_new=4)          # non-integer
    with pytest.raises(ValueError):
        eng.add_request([1, 2], max_new=4, deadline_s=-1.0)
    assert not eng.requests                     # nothing half-admitted
    assert eng.pool.num_free == eng.pool.num_pages


def test_sampler_rejects_bad_temperature():
    logits = np.zeros((1, 8), np.float32)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        sampler.sample(logits, key, -0.5)
    with pytest.raises(ValueError):
        sampler.sample(logits, key, float("nan"))


# --------------------------------------------------------------------- #
# lifecycle: cancel / deadline / queue timeout / on_done
# --------------------------------------------------------------------- #
def test_cancel_releases_kv_and_fires_on_done():
    done = {}
    eng = _engine()
    rids = [eng.add_request(p, max_new=6,
                            on_done=lambda r, why: done.setdefault(r, why))
            for p in _prompts(2)]
    eng.step(); eng.step()                      # mid-flight
    assert eng.cancel(rids[0])
    assert eng.requests[rids[0]].state == CANCELLED
    assert done[rids[0]] == "cancelled"
    assert not eng.cancel(rids[0])              # already terminal
    assert not eng.cancel(999)                  # unknown rid
    eng.run(16)
    assert eng.requests[rids[1]].state == DONE
    assert done[rids[1]] == "done"
    # the cancelled request's private KV is gone; nothing leaks
    assert eng.shutdown()["used_pages"] == 0
    assert eng.stats["cancelled"] == 1


def test_cancel_waiting_request_leaves_queue():
    clock = FakeClock()
    eng = _engine(max_running=1, clock=clock)
    r0 = eng.add_request(_prompts(2)[0], max_new=4)
    r1 = eng.add_request(_prompts(2)[1], max_new=4)
    eng.step()
    assert eng.requests[r1].state == "waiting"
    assert eng.cancel(r1)
    assert r1 not in eng.admission.queue
    eng.run(16)
    assert eng.requests[r0].state == DONE
    assert eng.shutdown()["used_pages"] == 0


def test_deadline_times_out_midflight():
    clock = FakeClock()
    done = {}
    eng = _engine(clock=clock)
    r0 = eng.add_request(_prompts(2)[0], max_new=8, deadline_s=2.5,
                         on_done=lambda r, why: done.setdefault(r, why))
    r1 = eng.add_request(_prompts(2)[1], max_new=4)
    for _ in range(8):
        eng.step()
        clock.t += 1.0
    assert eng.requests[r0].state == TIMED_OUT
    assert done[r0] == "deadline"
    assert len(eng.requests[r0].generated) <= 3
    assert eng.requests[r1].state == DONE       # neighbour unharmed
    assert eng.stats["timed_out"] == 1
    assert eng.shutdown()["used_pages"] == 0


def test_queue_timeout_before_admission():
    clock = FakeClock()
    eng = _engine(max_running=1, clock=clock)
    r0 = eng.add_request(_prompts(2)[0], max_new=8)
    r1 = eng.add_request(_prompts(2)[1], max_new=4, max_queue_s=1.5)
    for _ in range(4):
        eng.step()
        clock.t += 1.0
    assert eng.requests[r1].state == TIMED_OUT
    assert eng.requests[r1].finish_reason == "queue_timeout"
    assert eng.requests[r1].generated == []
    eng.run(16)
    assert eng.requests[r0].state == DONE


# --------------------------------------------------------------------- #
# callback isolation (regression: a raising on_token used to unwind
# the whole step, poisoning every request in the batch)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fused", [False, True])
def test_raising_on_token_fails_only_its_request(fused):
    streams = {}

    def good(rid, tok):
        streams.setdefault(rid, []).append(tok)

    def bad(rid, tok):
        raise RuntimeError("user bug")

    eng = _engine(fused=fused)
    rids = [eng.add_request(p, max_new=4,
                            on_token=bad if i == 1 else good)
            for i, p in enumerate(_prompts(3))]
    eng.run(16)
    assert eng.requests[rids[1]].state == FAILED
    assert eng.requests[rids[1]].finish_reason == "callback_error"
    assert eng.stats["callback_errors"] == 1
    # survivors decoded to completion, streams intact
    ref = _engine(fused=fused)
    for p in _prompts(3):
        ref.add_request(p, max_new=4)
    expect = ref.run(16)
    for i in (0, 2):
        assert eng.requests[rids[i]].state == DONE
        assert streams[rids[i]] == expect[rids[i]]
    assert eng.shutdown()["used_pages"] == 0


def test_raising_on_done_counts_but_other_streams_survive():
    def bad_done(rid, why):
        raise RuntimeError("user bug in close")

    eng = _engine()
    r0 = eng.add_request(_prompts(2)[0], max_new=3, on_done=bad_done)
    r1 = eng.add_request(_prompts(2)[1], max_new=3)
    eng.run(16)
    assert eng.requests[r0].state == FAILED
    assert eng.requests[r0].finish_reason == "callback_error"
    assert len(eng.requests[r0].generated) == 3   # tokens were streamed
    assert eng.requests[r1].state == DONE
    assert eng.stats["callback_errors"] == 1
    assert eng.shutdown()["used_pages"] == 0


# --------------------------------------------------------------------- #
# injected faults: recovery + survivor parity
# --------------------------------------------------------------------- #
def _run_plain(max_new=4, **kw):
    eng = _engine(**kw)
    for p in _prompts(3):
        eng.add_request(p, max_new=max_new)
    return eng.run(24), eng


def test_alloc_and_dispatch_faults_are_absorbed():
    expect, ref = _run_plain()
    plan = FaultPlan([FaultSpec("alloc", 0),
                      FaultSpec("dispatch", 1, times=2),
                      FaultSpec("stall", 2, payload=0.001)])
    eng = _engine(faults=plan)
    for p in _prompts(3):
        eng.add_request(p, max_new=4)
    out = eng.run(24)
    assert out == expect                       # streams byte-identical
    assert eng.stats["dispatch_failures"] == 2
    assert eng.stats["dispatch_recoveries"] == 2
    assert eng.injector.pending() == 0
    eng.check()
    assert eng.shutdown()["used_pages"] == 0


def test_dispatch_ladder_exhaustion_raises():
    # more consecutive failures than the bounded retry allows: the
    # step surfaces the ResourceExhausted instead of looping forever
    from repro.serving.faults import ResourceExhausted
    plan = FaultPlan([FaultSpec("dispatch", 0, times=99)])
    eng = _engine(faults=plan, max_dispatch_retries=2)
    eng.add_request(_prompts(1)[0], max_new=4)
    with pytest.raises(ResourceExhausted):
        eng.run(8)
    eng.check()                                # state still consistent


@pytest.mark.parametrize("fused", [False, True])
def test_nan_injection_quarantines_row(fused):
    expect, _ = _run_plain(fused=fused)
    plan = FaultPlan([FaultSpec("nan_logits", 2, rid=1)])
    eng = _engine(fused=fused, nan_guard=True, faults=plan)
    rids = [eng.add_request(p, max_new=4) for p in _prompts(3)]
    eng.run(24)
    assert eng.requests[rids[1]].state == FAILED
    assert eng.requests[rids[1]].finish_reason == "nan_logits"
    assert eng.stats["nan_rows"] >= 1
    # the poisoned token never streamed; survivors are byte-identical
    assert expect[rids[1]][:len(eng.requests[rids[1]].generated)] \
        == eng.requests[rids[1]].generated
    for r in (rids[0], rids[2]):
        assert eng.requests[r].state == DONE
        assert eng.requests[r].generated == expect[r]
    eng.check()
    assert eng.shutdown()["used_pages"] == 0


def test_nan_guard_with_mesh_rejected():
    from repro.distributed.mesh import decode_mesh
    with pytest.raises(ValueError):
        _engine(nan_guard=True, mesh=decode_mesh(1, 1))


# --------------------------------------------------------------------- #
# invariant self-check
# --------------------------------------------------------------------- #
def test_check_passes_live_and_catches_planted_corruption():
    eng = _engine()
    rid = eng.add_request(_prompts(1)[0], max_new=4)
    eng.step(); eng.step()
    eng.check()                                  # healthy mid-flight
    # plant: a page id the allocator never handed out
    leaf = eng.forest.nodes[eng.forest.leaf_of[rid]]
    free_page = max(set(range(eng.pool.num_pages))
                    - set(eng.pool.allocator.used_page_ids()))
    leaf.page_ids.append(free_page)
    with pytest.raises(EngineInvariantError) as ei:
        eng.check()
    assert any("page" in f for f in ei.value.failures)
    leaf.page_ids.pop()
    eng.check()
    # plant: a pin the request never took
    eng.requests[rid].pinned.append(leaf.id)
    with pytest.raises(EngineInvariantError):
        eng.check()


def test_check_every_runs_periodically():
    eng = _engine(check_every=2)
    eng.add_request(_prompts(1)[0], max_new=6)
    eng.run(16)
    assert eng.stats["invariant_checks"] >= 3


# --------------------------------------------------------------------- #
# property: chaos mix always quiesces, in every engine mode
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["eager", "fused", "cached", "spec"])
def test_chaos_mix_quiesces_all_modes(mode):
    kw = {}
    if mode == "fused":
        kw["fused"] = True
    elif mode == "cached":
        from repro.serving.cache import CachePolicy
        kw["cache"] = CachePolicy()
    elif mode == "spec":
        from repro.serving.speculation import SpecConfig
        kw["speculative"] = SpecConfig(depth=2, branch=2, max_nodes=3)

    # alloc seams are only visited on admission/growth (and gated off
    # under speculation), so the seeded draw sticks to always-visited
    # kinds and alloc gets one pinned spec that meets the first prefill
    kinds = tuple(k for k in KINDS if k != "alloc")
    specs = list(FaultPlan.seeded(11, steps=6, rate=0.2,
                                  kinds=kinds).specs)
    specs += [FaultSpec("dispatch", 1), FaultSpec("nan_logits", 3)]
    if mode != "spec":
        specs.append(FaultSpec("alloc", 0))
    clock = FakeClock()
    eng = _engine(faults=FaultPlan(specs), nan_guard=True,
                  check_every=3, clock=clock, **kw)
    rids = [eng.add_request(p, max_new=4,
                            deadline_s=2.5 if i == 2 else None)
            for i, p in enumerate(_prompts(3))]
    eng.cancel(rids[0])
    for _ in range(40):
        if not eng.has_work():
            break
        eng.step()
        clock.t += 1.0
    assert not eng.has_work(), "chaos mix did not drain"
    assert all(q.finished for q in eng.requests.values())
    # with this tiny workload the engine may drain before every seeded
    # spec's seam is revisited; full-schedule quiescence is asserted by
    # benchmarks/chaos_replay.py on the larger CI workload
    assert eng.injector.total_fired > 0
    for q in eng.requests.values():
        if q.state == FAILED:
            assert q.finish_reason in ("nan_logits", "callback_error")
    eng.check()
    assert eng.shutdown()["used_pages"] == 0
