"""Fused single-dispatch decode step (``serving/step_fn.py``).

Covers the three invariants the fused path must hold:

* **equivalence** — greedy token streams byte-identical to the eager
  per-layer path for every registered backend, across a bucket-boundary
  crossing (the batch grows past a power of two mid-decode);
* **compile-cache bound** — arrivals, completions, and an eviction must
  not recompile the fused step beyond the distinct shape buckets (no
  per-step recompiles);
* **single dispatch / async** — exactly one jitted call per decode
  step, with sampled tokens deferred on device between sync points.
"""

import jax
import pytest

from repro.configs import smoke_config
from repro.kernels import registry
from repro.models import transformer as T
from repro.serving.engine import PENDING_DEVICE, DecodeEngine

CFG = smoke_config("qwen2.5-14b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
PAGE = 8
DOC = list(range(10, 10 + 32))


def _engine(backend, fused, **kw):
    kwargs = dict(page_size=PAGE, num_pages=256, max_q=8,
                  temperature=0.0, fused=fused)
    kwargs.update(kw)
    return DecodeEngine(CFG, PARAMS, backend=backend, **kwargs)


def _bucket_crossing_run(backend, fused):
    """2 requests decode, then arrivals push the batch to 3 and 5 rows:
    the fused row bucket crosses 2 -> 4 -> 8 mid-decode."""
    eng = _engine(backend, fused)
    rids = [eng.add_request(DOC + [100 + i], max_new=10) for i in range(2)]
    eng.step(); eng.step()
    rids.append(eng.add_request(DOC + [200], max_new=8))   # bucket 2 -> 4
    eng.step(); eng.step()
    rids.append(eng.add_request(DOC + [210], max_new=6))
    rids.append(eng.add_request(DOC + [220], max_new=6))   # bucket 4 -> 8
    eng.run(32)
    outs = {i: list(eng.requests[r].generated) for i, r in enumerate(rids)}
    assert all(outs[i] for i in outs)
    return outs, eng


@pytest.mark.parametrize("backend", registry.names())
def test_fused_matches_eager_across_bucket_boundary(backend):
    ref, _ = _bucket_crossing_run(backend, fused=False)
    got, eng = _bucket_crossing_run(backend, fused=True)
    assert got == ref, backend
    if eng.fused:    # ref backend falls back to eager
        assert eng.stats["fused_calls"] == eng.stats["steps"]
        assert eng.fused_cache_size <= len(eng.bucket_signatures)


def test_ref_backend_falls_back_to_eager():
    eng = _engine("ref", fused=True)
    assert not eng.fused            # not jit-safe -> eager fallback
    eng.add_request(DOC + [100], max_new=3)
    outs = eng.run(8)
    assert len(next(iter(outs.values()))) == 3
    assert eng.stats["fused_calls"] == 0


def test_fused_compile_cache_bounded_by_buckets():
    """Engine lifecycle sweep — arrivals, completions, an eviction —
    with the jit cache-miss count bounded by the bucket count."""
    eng = _engine("codec-xla", fused=True, num_pages=9,
                  prefill_chunk=PAGE)
    doc = list(range(10, 10 + 48))
    rids = [eng.add_request(doc + [100 + 3 * i + j for j in range(3)],
                            max_new=8) for i in range(2)]
    eng.step(); eng.step()
    rids += [eng.add_request(doc + [200 + 3 * i + j for j in range(3)],
                             max_new=6) for i in range(2)]  # mid-decode
    eng.run(80)
    assert all(len(eng.requests[r].generated)
               == eng.requests[r].max_new for r in rids)
    assert eng.stats["preempted"] >= 1                # eviction fired
    assert eng.stats["fused_calls"] == eng.stats["steps"]
    # the core regression: compiles are bounded by distinct buckets,
    # NOT by steps or plan rebuilds
    assert eng.fused_cache_size <= len(eng.bucket_signatures)
    assert eng.fused_cache_size < eng.stats["steps"]
    assert eng.stats["replans"] >= len(eng.bucket_signatures)


def test_fused_is_single_dispatch_and_async():
    """One jitted call per decode step; between sync points the sampled
    tokens stay on device (placeholders in ``generated``)."""
    eng = _engine("codec-xla", fused=True)
    rid = eng.add_request(DOC + [100], max_new=8)
    eng._attend = None      # eager-only helper must never be touched
    eng.step(); eng.step(); eng.step()
    req = eng.requests[rid]
    assert eng.stats["fused_calls"] == 3
    assert req.pending is PENDING_DEVICE
    assert any(t < 0 for t in req.generated)      # deferred placeholders
    flushes = eng.stats["token_flushes"]
    eng.flush_tokens()
    assert eng.stats["token_flushes"] == flushes + 1
    assert all(t >= 0 for t in req.generated)
    assert isinstance(req.pending, int)
    # dispatch vs compute accounting (satellite): both recorded
    assert eng.stats["decode_dispatch_time"] > 0
    assert eng.stats["decode_sync_time"] > 0
    assert any("dispatch_time" in s for s in eng.step_stats)


def test_eager_step_stats_report_dispatch_and_compute():
    eng = _engine("codec-xla", fused=False)
    eng.add_request(DOC + [100], max_new=2)
    eng.run(4)
    rows = [s for s in eng.step_stats if s.get("decoded")]
    assert rows and all("dispatch_time" in s and "compute_time" in s
                        for s in rows)
    assert eng.stats["decode_time"] >= eng.stats["decode_dispatch_time"]


def test_fused_hybrid_mamba_matches_eager():
    """Batched per-request SSM state (gather/scatter at epoch
    boundaries) must not change hybrid-arch streams."""
    cfg = smoke_config("jamba-v0.1-52b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 42))
    prompts = [doc + [100 + i, 101 + i] for i in range(2)]
    outs = {}
    for fused in (False, True):
        eng = DecodeEngine(cfg, params, page_size=PAGE, num_pages=256,
                           backend="codec-xla", max_q=8, temperature=0.0,
                           fused=fused)
        for p in prompts:
            eng.add_request(p, max_new=4)
        outs[fused] = eng.run(8)
    assert outs[False] == outs[True]


def test_fused_sliding_window_matches_eager():
    """Per-window plans ride through the fused step (gemma3: 5 local : 1
    global layer pattern)."""
    cfg = smoke_config("gemma3-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 74))
    prompts = [doc + [100 + i, 101 + i] for i in range(2)]
    outs = {}
    for fused in (False, True):
        eng = DecodeEngine(cfg, params, page_size=16, num_pages=256,
                           backend="codec-xla", max_q=8, temperature=0.0,
                           fused=fused)
        for p in prompts:
            eng.add_request(p, max_new=4)
        outs[fused] = eng.run(8)
    assert outs[False] == outs[True]


def test_fused_sampled_decoding_matches_eager():
    """temperature > 0: per-row ``fold_in`` sampling makes the draws
    independent of the fused bucket padding, so stochastic streams also
    match eager exactly (same seed, same split cadence)."""
    outs = {}
    for fused in (False, True):
        eng = _engine("codec-xla", fused=fused, temperature=0.8, seed=3)
        for i in range(3):
            eng.add_request(DOC + [100 + i], max_new=5)
        outs[fused] = eng.run(10)
    assert outs[False] == outs[True]


def test_fused_release_and_leak_free():
    eng = _engine("codec-xla", fused=True)
    rids = [eng.add_request(DOC + [100 + i], max_new=4) for i in range(2)]
    eng.run(16)
    for r in rids:
        eng.release(r)
    assert eng.pool.num_free == eng.pool.num_pages
    eng.pool.allocator.check()
    assert set(eng.forest.nodes) == {0}
