"""Regression tests for the §Perf optimizations (numerics must not move)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import layers as L, transformer as T
from repro.training import trainer
from repro.training.optimizer import cosine_schedule, make_optimizer


def test_ce_onehot_equals_gather():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 7, 41))
    labels = jax.random.randint(jax.random.PRNGKey(1), (3, 7), 0, 41)
    a = trainer.cross_entropy(logits, labels)
    b = trainer.cross_entropy_onehot(logits, labels)
    assert abs(float(a - b)) < 1e-6


def test_moe_groups_parity_no_drop():
    cfg = smoke_config("kimi-k2-1t-a32b")     # capacity_factor=0 (no drop)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y1, _ = L.apply_moe(p, cfg, x)
    for g in (2, 4, 8):
        y2, _ = L.apply_moe(p, dataclasses.replace(cfg, moe_groups=g), x)
        np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)


def test_moe_groups_capacity_is_per_group():
    """With a tight capacity, grouping changes WHICH tokens drop (local
    queues) but never produces non-finite output."""
    cfg = dataclasses.replace(smoke_config("jamba-v0.1-52b"),
                              capacity_factor=0.4)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    for g in (1, 2, 4):
        y, aux = L.apply_moe(p, dataclasses.replace(cfg, moe_groups=g), x)
        assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.isfinite(aux))


def test_microbatch_unroll_equals_scan():
    cfg = smoke_config("gemma-2b")
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 10))
    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(2))
    outs = []
    for unroll in (False, True):
        step = trainer.make_train_step(cfg, opt, microbatches=2,
                                       remat=False, unroll=unroll)
        s2, m = jax.jit(step)(state, (toks, labels))
        outs.append((float(m["loss"]),
                     jax.tree.leaves(s2.params)[0]))
    assert abs(outs[0][0] - outs[1][0]) < 1e-5
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-5,
                               atol=1e-6)


def test_microbatch_equals_full_batch_loss():
    """Accumulated microbatch loss == single-batch loss (linearity)."""
    cfg = smoke_config("qwen2.5-14b")
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 10))
    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 12), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                cfg.vocab_size)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(2))
    s1, m1 = jax.jit(trainer.make_train_step(cfg, opt, remat=False))(
        state, (toks, labels))
    s4, m4 = jax.jit(trainer.make_train_step(cfg, opt, microbatches=4,
                                             remat=False))(
        state, (toks, labels))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4


def test_hint_noop_without_mesh():
    L.set_activation_mesh(None)
    x = jnp.ones((4, 8))
    assert L.hint(x, model_last=True) is x


def test_head_major_cache_layout():
    cfg = smoke_config("qwen2.5-14b")
    cache = T.init_cache(cfg, batch=3, max_len=32)
    k = jax.tree_util.tree_leaves(
        {"b": cache["blocks"]} if "blocks" in cache else cache)[0]
    # (periods, B, hkv, L, hd)
    sub = cache["blocks"]["sub0"]["k"]
    assert sub.shape == (cfg.num_periods, 3, cfg.num_kv_heads, 32,
                         cfg.head_dim)


@pytest.mark.parametrize("seed,E,k,cf", [
    (0, 2, 1, 0.2), (1, 4, 2, 1.0), (7, 6, 3, 1.5), (42, 3, 2, 0.5),
    (100, 5, 1, 0.8),
])
def test_sort_dispatch_matches_onehot_priority(seed, E, k, cf):
    """The O(n*k) sort-based dispatch drops exactly the same
    token-choices as the GShard cumsum-of-one-hot formulation."""
    n = 24
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, E, (n, k))
    cap = max(1, int(cf * n * k / E))
    # reference: cumsum of one-hot over flattened (n*k)
    flat = np.eye(E)[idx.reshape(-1)]
    pos_ref = (np.cumsum(flat, 0) * flat - 1).max(-1).astype(int)
    keep_ref = (pos_ref >= 0) & (pos_ref < cap)
    # sort-based (mirrors layers.apply_moe)
    eid = idx.reshape(-1)
    order = np.argsort(eid, kind="stable")
    counts = np.bincount(eid, minlength=E)
    starts = np.cumsum(counts) - counts
    pos_sorted = np.arange(n * k) - starts[eid[order]]
    pos = np.zeros(n * k, int)
    pos[order] = pos_sorted
    keep = pos < cap
    np.testing.assert_array_equal(keep, keep_ref)
    np.testing.assert_array_equal(pos[keep], pos_ref[keep])


def test_mha_kv_layout_parity():
    B, Tq, Tk, Hq, Hkv, d = 2, 1, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, d))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, d))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, d))
    o1 = L.mha(q, k, v, causal=False)
    o2 = L.mha(q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
               causal=False, kv_layout="bhld")
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-6)
