"""Quickstart: the CoDec shared-prefix attention op in 60 lines.

Builds a document-QA prefix forest (one shared doc, four questions),
compiles a decode plan, runs the attention through EVERY backend in
the registry (Pallas PAC kernel, XLA plan impl, the Hydragen batched
decomposition, the FlashDecoding baseline) against the python oracle,
and shows the IO the plan saves vs FlashDecoding.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel
from repro.kernels import registry

PAGE = 64
N_REQ, DOC_LEN, Q_LEN = 4, 1024, 96
H_Q, H_KV, D = 8, 2, 64          # GQA: 4 query heads per KV head

# 1. the KV-cache forest: a shared doc node + one private tail per request
forest = tree_mod.two_level(N_REQ, DOC_LEN, Q_LEN, block_size=PAGE)
pool_pages = plan_mod.assign_dense_pages(forest)
print(f"forest: {len(forest.real_nodes())} nodes, "
      f"{forest.total_tokens()} stored tokens for "
      f"{forest.total_context()} context tokens "
      f"(mean sharing degree {forest.mean_sharing_degree():.2f})")

# 2. compile the decode plan: cost estimation -> division -> LPT lanes
cm = CostModel(H_Q, H_KV, D, page_size=PAGE)
plan = plan_mod.build_plan(forest, cm, num_lanes=2, max_q=8)
print(f"plan: {plan.stats()}")

# 3. run the attention (paged KV pool layout = PagedAttention) through
#    every registered backend — switching is just a string
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (N_REQ, H_Q, D))              # one query/request
k_pool = jax.random.normal(kk, (pool_pages, PAGE, H_KV, D))
v_pool = jax.random.normal(kv, (pool_pages, PAGE, H_KV, D))

flash_plan = plan_mod.flash_plan(forest, cm, num_lanes=2, max_q=8)
out_ref = registry.get("ref")(q, k_pool, v_pool, plan)
for name in registry.names():
    if name == "ref":
        continue
    backend = registry.get(name)
    # a backend declares which planner it wants (flash = per-request)
    p = flash_plan if backend.plan_kind == "flash" else plan
    out = backend(q, k_pool, v_pool, p)
    err = float(jnp.abs(out - out_ref).max())
    print(f"{name:13s} vs ref max |err|: {err:.2e}   "
          f"(plan_kind={backend.plan_kind}, tasks={p.num_tasks}, "
          f"window={backend.supports_window}, gqa={backend.supports_gqa})")

# 4. what did prefix sharing buy? (paper Fig. 6 metric)
io_codec = forest.codec_io_bytes(H_KV, D)
io_flash = forest.flash_io_bytes(H_KV, D)
print(f"KV bytes/step: codec {io_codec / 1e6:.2f} MB, "
      f"flash-decoding {io_flash / 1e6:.2f} MB "
      f"-> {io_flash / io_codec:.2f}x reduction")
