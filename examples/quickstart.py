"""Quickstart: the CoDec shared-prefix attention op in 60 lines.

Builds a document-QA prefix forest (one shared doc, four questions),
compiles a decode plan, and runs the attention three ways — the Pallas
PAC kernel (interpret mode on CPU), the XLA plan implementation, and
the python oracle — and shows the IO the plan saves vs FlashDecoding.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel
from repro.kernels import ops

PAGE = 64
N_REQ, DOC_LEN, Q_LEN = 4, 1024, 96
H_Q, H_KV, D = 8, 2, 64          # GQA: 4 query heads per KV head

# 1. the KV-cache forest: a shared doc node + one private tail per request
forest = tree_mod.two_level(N_REQ, DOC_LEN, Q_LEN, block_size=PAGE)
pool_pages = plan_mod.assign_dense_pages(forest)
print(f"forest: {len(forest.real_nodes())} nodes, "
      f"{forest.total_tokens()} stored tokens for "
      f"{forest.total_context()} context tokens "
      f"(mean sharing degree {forest.mean_sharing_degree():.2f})")

# 2. compile the decode plan: cost estimation -> division -> LPT lanes
cm = CostModel(H_Q, H_KV, D, page_size=PAGE)
plan = plan_mod.build_plan(forest, cm, num_lanes=2, max_q=8)
print(f"plan: {plan.stats()}")

# 3. run the attention (paged KV pool layout = PagedAttention)
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (N_REQ, H_Q, D))              # one query/request
k_pool = jax.random.normal(kk, (pool_pages, PAGE, H_KV, D))
v_pool = jax.random.normal(kv, (pool_pages, PAGE, H_KV, D))

out_pallas = ops.codec_attention(q, k_pool, v_pool, plan, impl="pallas")
out_xla = ops.codec_attention(q, k_pool, v_pool, plan, impl="xla")
out_ref = ops.codec_attention(q, k_pool, v_pool, plan, impl="ref")
print("pallas vs ref max |err|:",
      float(jnp.abs(out_pallas - out_ref).max()))
print("xla    vs ref max |err|:",
      float(jnp.abs(out_xla - out_ref).max()))

# 4. what did prefix sharing buy? (paper Fig. 6 metric)
io_codec = forest.codec_io_bytes(H_KV, D)
io_flash = forest.flash_io_bytes(H_KV, D)
print(f"KV bytes/step: codec {io_codec / 1e6:.2f} MB, "
      f"flash-decoding {io_flash / 1e6:.2f} MB "
      f"-> {io_flash / io_codec:.2f}x reduction")
