"""Tree-structured speculative verification on the CoDec forest.

The paper's §2.5 motivation beyond document QA: in speculative decoding
the verifier scores a *tree* of draft continuations, where sibling
branches share all ancestor KV.  That is exactly a CoDec forest — each
draft branch is a leaf, the trunk + ancestor drafts are shared nodes,
and one CoDec plan computes attention for every branch head while
reading each shared node once.

Part 1 shows the plan-level mechanics (forest -> verify plan -> one
attention call for all branch heads, checked against a dense oracle);
part 2 runs the real thing: ``DecodeEngine(speculative=True)``, the
draft-propose / tree-verify / accept-rollback serving loop (DESIGN.md
§10), committing multiple tokens per dispatch with token streams
byte-identical to non-speculative decode.

    PYTHONPATH=src python examples/tree_speculation.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel
from repro.kernels import ops, ref

PAGE = 32
TRUNK = 8 * PAGE          # the accepted context so far
DRAFT_DEPTH, ARITY = 3, 2  # a binary draft tree, 8 branch heads
DRAFT_CHUNK = PAGE         # tokens per draft node (chunked drafts)
H_Q, H_KV, D = 8, 2, 64

# 1. forest: trunk -> draft tree; one "query" per branch head.
#    (tree.add_node is the public grow API; the serving engine's
#    speculation path uses its sibling add_draft for 1-token nodes.)
forest = tree_mod.PrefixForest(PAGE)
trunk = forest.add_node(tree_mod.ROOT_ID, TRUNK)
frontier = [trunk]
for _ in range(DRAFT_DEPTH):
    frontier = [forest.add_node(n.id, DRAFT_CHUNK)
                for n in frontier for _ in range(ARITY)]
for rid, leaf in enumerate(frontier):
    forest.attach_request(rid, leaf.id)
forest.validate()
B = len(frontier)
print(f"draft tree: {len(forest.real_nodes())} nodes, {B} branch heads, "
      f"{forest.total_tokens()} stored vs {forest.total_context()} "
      f"context tokens (sharing degree "
      f"{forest.mean_sharing_degree():.2f})")

# 2. one plan for the whole verification step
pool_pages = plan_mod.assign_dense_pages(forest)
cm = CostModel(H_Q, H_KV, D, page_size=PAGE)
plan = plan_mod.build_verify_plan(forest, cm,
                                  {r: r for r in range(B)},
                                  num_lanes=2, max_q=B)
print("plan:", plan.stats())

key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, H_Q, D))           # one head per branch
k_pool = jax.random.normal(kk, (pool_pages, PAGE, H_KV, D))
v_pool = jax.random.normal(kv, (pool_pages, PAGE, H_KV, D))

out = ops.codec_attention(q, k_pool, v_pool, plan, impl="pallas")

# 3. oracle check: per-branch dense attention over its materialised path
#    (tests/test_speculation.py keeps this exact property under pytest)
for rid in range(B):
    ks, vs = [], []
    for node in forest.path(rid):
        for j, pg in enumerate(node.page_ids):
            take = min(PAGE, node.length - j * PAGE)
            ks.append(k_pool[pg][:take])
            vs.append(v_pool[pg][:take])
    kd, vd = jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)
    o_ref, _, _ = ref.pac_ref(q[rid][None], kd, vd)
    err = float(jnp.abs(out[rid] - o_ref[0]).max())
    assert err < 1e-5, (rid, err)
print(f"all {B} branch heads match the dense oracle")

# 4. what did the tree buy? (per verification step)
io_codec = forest.codec_io_bytes(H_KV, D)
io_flash = forest.flash_io_bytes(H_KV, D)
print(f"KV bytes/verify-step: tree-shared {io_codec / 1e6:.2f} MB vs "
      f"per-branch {io_flash / 1e6:.2f} MB "
      f"({io_flash / io_codec:.2f}x saved — grows with trunk length)")

# ---------------------------------------------------------------------- #
# 5. the serving loop: speculative mode end-to-end (DESIGN.md §10).
#    A repetitive prompt gives the self-drafting n-gram proposer
#    something to match; the engine then commits >1 token per dispatch
#    while producing exactly the non-speculative greedy stream.
# ---------------------------------------------------------------------- #
from repro.configs import smoke_config              # noqa: E402
from repro.models import transformer as T           # noqa: E402
from repro.serving.engine import DecodeEngine       # noqa: E402

cfg = smoke_config("qwen2.5-14b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompt = (list(rng.integers(0, cfg.vocab_size, 8)) * 4)[:32]


def serve(speculative):
    eng = DecodeEngine(cfg, params, page_size=8, num_pages=256,
                       backend="codec-xla", max_q=8, temperature=0.0,
                       speculative=speculative)
    r = eng.add_request(prompt, max_new=16)
    eng.run(64)
    return list(eng.requests[r].generated), dict(eng.stats)


base, st0 = serve(False)
spec, st1 = serve(True)
assert spec == base, "speculative stream must equal greedy decode"
acc = st1["spec_accepted"] / max(st1["spec_steps"], 1)
print(f"engine: {len(spec)} tokens in {st1['spec_steps']} dispatches "
      f"(vs {st0['steps']} non-speculative; {st1['spec_accepted']} "
      f"draft tokens accepted, {acc:.2f}/step) — streams identical")
