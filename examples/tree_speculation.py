"""Tree-structured speculative verification on the CoDec forest.

The paper's §2.5 motivation beyond document QA: in speculative decoding
the verifier scores a *tree* of draft continuations, where sibling
branches share all ancestor KV.  That is exactly a CoDec forest — each
draft branch is a leaf, the trunk + ancestor drafts are shared nodes,
and one CoDec plan computes attention for every branch head while
reading each shared node once.

    PYTHONPATH=src python examples/tree_speculation.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel
from repro.kernels import ops, ref

PAGE = 32
TRUNK = 8 * PAGE          # the accepted context so far
DRAFT_DEPTH, ARITY = 3, 2  # a binary draft tree, 8 branch heads
DRAFT_CHUNK = PAGE         # tokens per draft node (chunked drafts)
H_Q, H_KV, D = 8, 2, 64

# 1. forest: trunk -> draft tree; one "query" per branch head
forest = tree_mod.PrefixForest(PAGE)
trunk = forest._new_node(tree_mod.ROOT_ID, TRUNK, 0)
frontier = [trunk]
for _ in range(DRAFT_DEPTH):
    frontier = [forest._new_node(n.id, DRAFT_CHUNK, n.end_pos)
                for n in frontier for _ in range(ARITY)]
for rid, leaf in enumerate(frontier):
    forest.attach_request(rid, leaf.id)
forest.validate()
B = len(frontier)
print(f"draft tree: {len(forest.real_nodes())} nodes, {B} branch heads, "
      f"{forest.total_tokens()} stored vs {forest.total_context()} "
      f"context tokens (sharing degree "
      f"{forest.mean_sharing_degree():.2f})")

# 2. one plan for the whole verification step
pool_pages = plan_mod.assign_dense_pages(forest)
cm = CostModel(H_Q, H_KV, D, page_size=PAGE)
plan = plan_mod.build_plan(forest, cm, num_lanes=2, max_q=B)
print("plan:", plan.stats())

key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, H_Q, D))           # one head per branch
k_pool = jax.random.normal(kk, (pool_pages, PAGE, H_KV, D))
v_pool = jax.random.normal(kv, (pool_pages, PAGE, H_KV, D))

out = ops.codec_attention(q, k_pool, v_pool, plan, impl="pallas")

# 3. oracle check: per-branch dense attention over its materialised path
for rid in range(B):
    ks, vs = [], []
    for node in forest.path(rid):
        for j, pg in enumerate(node.page_ids):
            take = min(PAGE, node.length - j * PAGE)
            ks.append(k_pool[pg][:take])
            vs.append(v_pool[pg][:take])
    kd, vd = jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)
    o_ref, _, _ = ref.pac_ref(q[rid][None], kd, vd)
    err = float(jnp.abs(out[rid] - o_ref[0]).max())
    assert err < 1e-5, (rid, err)
print(f"all {B} branch heads match the dense oracle")

# 4. what did the tree buy? (per verification step)
io_codec = forest.codec_io_bytes(H_KV, D)
io_flash = forest.flash_io_bytes(H_KV, D)
print(f"KV bytes/verify-step: tree-shared {io_codec / 1e6:.2f} MB vs "
      f"per-branch {io_flash / 1e6:.2f} MB "
      f"({io_flash / io_codec:.2f}x saved — grows with trunk length)")
