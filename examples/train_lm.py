"""Train a ~100M-parameter LM for a few hundred steps on CPU.

Exercises the full training substrate end-to-end: sharded train step
(1-device mesh here; the identical code lowers on the 512-chip mesh in
the dry-run), deterministic data pipeline, cosine schedule, grad clip,
checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.training import checkpoint as ckpt
from repro.training import trainer
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import cosine_schedule, make_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/codec_train_lm")
args = ap.parse_args()

# ~100M params: gemma3-1b backbone, 6 layers, 16k vocab
cfg = dataclasses.replace(
    get_config("gemma3-1b"), name="gemma3-100m",
    num_layers=6, vocab_size=16384, dtype="float32",
    sliding_window=64)
n_params = cfg.param_count()
print(f"model: {cfg.name}, ~{n_params / 1e6:.0f}M params")

opt = make_optimizer("adamw", cosine_schedule(3e-4, 20, args.steps))
step_fn = jax.jit(trainer.make_train_step(cfg, opt, remat=False),
                  donate_argnums=(0,))
state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))

start = 0
restored = ckpt.load_latest(args.ckpt_dir, state)
if restored:
    start, state, _ = restored
    print(f"resumed from step {start}")

data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch),
                   start_step=start)
t0 = time.time()
for step in range(start, args.steps):
    toks, labels = data.batch(step)
    state, m = step_fn(state, (jnp.asarray(toks), jnp.asarray(labels)))
    if step % 25 == 0 or step == args.steps - 1:
        print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
              f"gnorm {float(m['grad_norm']):.3f}  "
              f"{(time.time() - t0) / max(step - start + 1, 1):.2f}s/step")
    if step and step % 100 == 0:
        ckpt.save_checkpoint(args.ckpt_dir, step, state)
ckpt.save_checkpoint(args.ckpt_dir, args.steps, state)
print(f"done in {time.time() - t0:.0f}s; final loss "
      f"{float(m['loss']):.4f}")
