"""End-to-end driver: serve a small model with batched shared-prefix
requests through the CoDec decode engine (the paper's deployment kind).

Three question waves arrive against two shared documents (continuous
batching); CoDec combines the shared KV reads, the plan is reused
across steps, and the same run is repeated with the FlashDecoding
backend to verify identical outputs and show the IO gap.  A final run
deliberately undersizes the KV pool and enables chunked prefill: the
engine preempts-and-recomputes instead of failing, and still produces
byte-identical tokens.

    PYTHONPATH=src python examples/serve_docqa.py
"""

import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine

ARCH = "qwen2.5-14b"          # GQA family (reduced smoke config on CPU)
cfg = smoke_config(ARCH)
params = T.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

doc_a = rng.integers(0, cfg.vocab_size, 128).tolist()
doc_b = rng.integers(0, cfg.vocab_size, 96).tolist()


def questions(doc, n):
    return [doc + rng.integers(0, cfg.vocab_size, 6).tolist()
            for _ in range(n)]


# fixed workload, shared by both backend runs
WAVE1 = questions(doc_a, 3)
WAVE2 = questions(doc_b, 2)
WAVE3 = questions(doc_a, 2)


def run(backend: str, num_pages: int = 2048, mesh=None, **policy):
    eng = DecodeEngine(cfg, params, page_size=16, num_pages=num_pages,
                       backend=backend, max_q=16, temperature=0.0,
                       mesh=mesh, **policy)
    t0 = time.time()
    # wave 1: three questions on doc A
    for p in WAVE1:
        eng.add_request(p, max_new=12)
    for _ in range(3):
        eng.step()
    # wave 2 arrives mid-decode (continuous batching): doc B
    for p in WAVE2:
        eng.add_request(p, max_new=12)
    # wave 3: more questions on doc A — its KV is already cached
    for p in WAVE3:
        eng.add_request(p, max_new=12)
    eng.run(48)
    dt = time.time() - t0
    st = eng.stats
    print(f"[{backend}@{num_pages}p] {len(eng.requests)} requests, "
          f"{st['steps']} decode steps in {dt:.1f}s; "
          f"prefill computed {st['prefill_tokens']} tokens "
          f"(prompts total {3 * 134 + 2 * 102 + 2 * 134}); "
          f"{st['replans']} replans, plan time {st['plan_time']:.3f}s")
    io_c = eng.forest.codec_io_bytes(cfg.num_kv_heads, cfg.head_dim)
    io_f = eng.forest.flash_io_bytes(cfg.num_kv_heads, cfg.head_dim)
    print(f"    decode KV IO: {io_c / 1e3:.1f} KB/step vs "
          f"{io_f / 1e3:.1f} KB/step per-request "
          f"({io_f / io_c:.2f}x saved)")
    if st["preempted"] or st["reclaimed"] or st["prefill_chunks"]:
        print(f"    pressure: peak {eng.pool.allocator.peak_used}/"
              f"{eng.pool.num_pages} pages, {st['preempted']} preemptions, "
              f"{st['reclaimed']} reclaims, {st['recompute_tokens']} "
              f"recomputed tokens, {st['prefill_chunks']} prefill chunks")
    if mesh is not None:
        occ = "/".join(f"{o:.0%}" for o in eng.pool.shard_occupancy())
        print(f"    mesh {mesh.shape['data']}x{mesh.shape['model']}: "
              f"per-shard pool occupancy {occ}")
    return {r: req.generated for r, req in eng.requests.items()}


out_codec = run("codec-pallas")
out_hydra = run("hydragen")
out_flash = run("flash")
assert out_codec == out_flash == out_hydra, \
    "backends must produce identical tokens"
print("codec == hydragen == flash outputs: OK")

# memory pressure: a pool too small to hold all waves at once, plus
# chunked prefill — same tokens, via preemption + recompute
out_tight = run("codec-pallas", num_pages=13, prefill_chunk=32,
                reserve_pages=0)
assert out_tight == out_codec, \
    "preempt-and-recompute must not change the tokens"
print("undersized pool (preemption + chunked prefill) outputs: OK")

# SPMD sharded serving (distributed/): the whole decode step traced
# under shard_map over a (data, model) mesh.  In-process this demo gets
# whatever devices exist (a 1x1 mesh on a plain run — the full sharded
# code path, collectives degenerate); launch/serve.py --mesh DxM runs
# real multi-device meshes via fake host devices.
from repro.distributed import decode_mesh  # noqa: E402

mesh = decode_mesh(1, 1)
out_mesh = run("codec-xla", mesh=mesh, fused=True)
assert out_mesh == out_codec, \
    "sharded engine must reproduce the single-device tokens"
print("SPMD mesh engine outputs: OK")
