"""Decode-trajectory benchmark: fused vs eager TPOT + baseline artifact.

Drives the SAME doc-QA forest through the eager per-layer decode loop
and the fused single-dispatch step (``serving/step_fn.py``) and writes a
``BENCH_decode.json`` trajectory artifact — TPOT, steps/s, fused compile
count, plan-rebuild count, per-step stats — so future PRs have a perf
baseline to regress against.

Each engine runs two passes over the same shared document (the second
pass re-uses the radix-cached prefix AND the warm jit cache, so it is
steady-state decode); the reported TPOT comes from the warm pass.
"""

from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import emit
from repro.configs import smoke_config
from repro.core import metrics as metrics_mod
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine
from repro.serving.telemetry import Telemetry

ARCH = os.environ.get("BENCH_DECODE_ARCH", "qwen2.5-14b")
BACKEND = os.environ.get("BENCH_DECODE_BACKEND", "codec-xla")
OUT = os.environ.get("BENCH_DECODE_OUT", "BENCH_decode.json")
PAGE = 16
DOC_LEN = 96
REQUESTS = 4
MAX_NEW = 16


def _snapshot(eng):
    return eng.publish_metrics().snapshot()


def _delta(a, b):
    """Pass summary from a metrics-registry delta: counters map to the
    legacy stat names, timing comes from the histogram sums."""
    d = metrics_mod.delta(b, a)
    return {"steps": d["decode_steps"]["value"],
            "replans": d["plan_rebuilds"]["value"],
            "token_flushes": d["token_flushes"]["value"],
            "fused_calls": d["fused_dispatches"]["value"],
            "prefill_tokens": d["prefill_tokens"]["value"],
            "decode_dispatch_time": d["dispatch_s"]["sum"],
            "decode_sync_time": d["flush_s"]["sum"]}


def _drive(eng, prompts):
    """Prefill the batch, then time the pure decode stream (prefill and
    its jit compiles are real but are not TPOT; the first step absorbs
    them plus the first plan epoch)."""
    for p in prompts:
        eng.add_request(p, max_new=MAX_NEW)
    eng.step()
    t0 = time.perf_counter()
    while eng.has_work():
        eng.step()
    eng.flush_tokens()
    jax.block_until_ready(eng.pool.k)
    return time.perf_counter() - t0


def run_engine(cfg, params, doc, fused):
    eng = DecodeEngine(cfg, params, page_size=PAGE, num_pages=2048,
                       backend=BACKEND, max_q=max(REQUESTS, 8),
                       temperature=0.0, fused=fused,
                       telemetry=Telemetry())
    passes = []
    for pno in range(2):
        prompts = [doc + [200 + 16 * pno + 4 * i + j for j in range(4)]
                   for i in range(REQUESTS)]
        before = _snapshot(eng)
        steps0 = len(eng.step_stats)
        wall = _drive(eng, prompts)
        d = _delta(before, _snapshot(eng))
        steps = max(d["steps"] - 1, 1)          # first step untimed
        d["wall_s"] = wall
        d["tpot_ms"] = wall / steps * 1e3
        d["steps_per_s"] = steps / max(wall, 1e-9)
        d["trajectory"] = eng.step_stats[steps0:]
        passes.append(d)
    warm = passes[1]
    warm["compile_count"] = eng.fused_cache_size
    warm["bucket_signatures"] = len(eng.bucket_signatures)
    warm["fused_active"] = eng.fused
    return passes


def main() -> None:
    cfg = smoke_config(ARCH)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 10 + DOC_LEN))
    result = {"arch": ARCH, "backend": BACKEND,
              "config": dict(page_size=PAGE, doc_len=DOC_LEN,
                             requests=REQUESTS, max_new=MAX_NEW)}
    for name, fused in (("eager", False), ("fused", True)):
        cold, warm = run_engine(cfg, params, doc, fused)
        result[name] = {"cold": {k: v for k, v in cold.items()
                                 if k != "trajectory"},
                        **{k: v for k, v in warm.items()
                           if k != "trajectory"},
                        "trajectory": warm["trajectory"]}
        emit("decode_trajectory", name,
             us_per_call=warm["tpot_ms"] * 1e3,
             tpot_ms=warm["tpot_ms"], steps_per_s=warm["steps_per_s"],
             steps=warm["steps"], replans=warm["replans"],
             compiles=warm.get("compile_count", 0))
    speedup = (result["eager"]["tpot_ms"]
               / max(result["fused"]["tpot_ms"], 1e-9))
    result["fused_speedup"] = speedup
    emit("decode_trajectory", "speedup", fused_over_eager=speedup)
    with open(OUT, "w") as f:
        json.dump(result, f, indent=1)
    print(f"# wrote {OUT}: fused TPOT {result['fused']['tpot_ms']:.2f} ms "
          f"vs eager {result['eager']['tpot_ms']:.2f} ms "
          f"({speedup:.1f}x)")


if __name__ == "__main__":
    main()
