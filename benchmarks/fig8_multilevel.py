"""Paper Fig. 8: CoDec vs FlashInfer-style multilevel cascade attention.

Cascade = two-phase execution: one kernel over the shared level (all
queries vs the shared node), then per-request kernels over the unique
tails — each phase partitioned independently, no cross-phase balancing.
CoDec's advantage (the paper's claim) is (1) global-view partitioning
across the whole forest and (2) one flattened reduction; we model the
cascade by scheduling each tree level as its own LPT problem and summing
level makespans (phases are separated by a sync).

Workload: LooGLE-like document QA — 20-36k-token documents, a handful
of questions each (matches the dataset stats in the paper's Fig. 8a).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import emit, paper_cost_model
from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.scheduler import TaskSpec, divide_and_schedule

PAGE = 64
LANES = 8

LOOGLE = {  # avg tokens per doc family (paper Fig. 8a)
    "arxiv": 20_887,
    "wiki": 21_017,
    "scripts": 36_412,
}


def cascade_makespan(forest, cm) -> float:
    """Per-level LPT, phases synced (the multilevel cascade pattern)."""
    depth_of = {}
    for node in forest.real_nodes():
        d = 0
        nid = node.id
        while forest.nodes[nid].parent != tree_mod.ROOT_ID:
            nid = forest.nodes[nid].parent
            d += 1
        depth_of.setdefault(d, []).append(node)
    total = 0.0
    for d, nodes in sorted(depth_of.items()):
        tasks = [TaskSpec(n.id, len(n.requests), n.length) for n in nodes]
        sched = divide_and_schedule(tasks, cm, LANES, PAGE,
                                    max_kv_per_task=8192)
        # each level = separate attention kernel + separate reduction
        # kernel launch (the overhead CoDec's single flattened reduction
        # avoids, paper §8 "multilevel attention")
        total += sched.makespan + 2 * cm.hw.launch_overhead
    return total


def main() -> None:
    cm = paper_cost_model(PAGE)
    # shared-ratio sweep at fixed context (the paper's micro-benchmark)
    for ratio in (0.5, 0.7, 0.9, 0.99):
        f = tree_mod.shared_ratio(32, 120_000, ratio, PAGE)
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, LANES, 256, 8192)
        mk_cascade = cascade_makespan(f, cm)
        emit("fig8_ratio", f"r{ratio}",
             codec_ms=pc.makespan * 1e3, cascade_ms=mk_cascade * 1e3,
             advantage=mk_cascade / max(pc.makespan, 1e-12))

    # deep / irregular trees: cascade syncs once per level, CoDec
    # schedules the whole forest at once (the paper's claimed edge)
    deep = {
        "kary_d6": tree_mod.full_kary(6, 2, 4096, PAGE),
        "degenerate_d12": tree_mod.degenerate(12, 8192, PAGE),
        "degenerate_d24": tree_mod.degenerate(24, 4096, PAGE),
    }
    for name, f in deep.items():
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, LANES, 256, 8192)
        mk_cascade = cascade_makespan(f, cm)
        emit("fig8_deep", name,
             codec_ms=pc.makespan * 1e3, cascade_ms=mk_cascade * 1e3,
             advantage=mk_cascade / max(pc.makespan, 1e-12))

    # LooGLE-like doc-QA trees: one doc shared by q questions
    for name, doc_len in LOOGLE.items():
        f = tree_mod.PrefixForest(PAGE)
        rid = 0
        for _ in range(8):            # 8 documents in the batch
            doc = f.add_node(tree_mod.ROOT_ID, doc_len // PAGE * PAGE)
            for _ in range(4):        # 4 questions per doc (91% sharing)
                leaf = f.add_node(doc.id, 64)
                f.attach_request(rid, leaf.id)
                rid += 1
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, LANES, 256, 8192)
        pf = plan_mod.flash_plan(f, cm, LANES, 256, 8192)
        mk_cascade = cascade_makespan(f, cm)
        emit("fig8_loogle", name,
             codec_ms=pc.makespan * 1e3,
             cascade_ms=mk_cascade * 1e3,
             flash_ms=pf.makespan * 1e3,
             sharing=f.mean_sharing_degree())


if __name__ == "__main__":
    main()
