"""Shared benchmark utilities.

Wall-clock on this CPU container is NOT the deliverable (kernels run in
interpret mode); each benchmark therefore reports *analytic* quantities
derived from the same machinery the TPU path uses — exact IO byte
counts, cost-model makespans, plan statistics — plus CPU wall time where
it is meaningful (plan construction, end-to-end smoke decode).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel

ROWS: List[Dict] = []


def emit(bench: str, name: str, us_per_call: float = 0.0, **derived):
    row = dict(bench=bench, name=name, us_per_call=us_per_call, **derived)
    ROWS.append(row)
    extras = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in derived.items())
    print(f"{bench},{name},{us_per_call:.2f},{extras}")
    return row


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6   # us


# Paper default model: qwen3-4b heads (32 q / 8 kv / d128)
def paper_cost_model(page_size: int = 64) -> CostModel:
    return CostModel(32, 8, 128, page_size=page_size)


def bench_backends(forest: tree_mod.PrefixForest, cm: CostModel,
                   num_lanes: int = 2, max_q: int = 16,
                   max_kv: int = 4096, repeats: int = 3,
                   backends=None) -> Dict[str, Dict]:
    """Execute every registered attention backend on the forest and
    report per-call wall time plus max |err| vs the python oracle.

    Interpret-mode Pallas makes absolute numbers meaningless on CPU —
    use small forests and read this as a numerics/agreement smoke plus
    a relative plan-overhead probe, not a kernel benchmark.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import registry

    pool_pages = plan_mod.assign_dense_pages(forest)
    ps = forest.block_size
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    B = len(forest.request_ids)
    q = jax.random.normal(kq, (B, cm.h_q, cm.d))
    k_pool = jax.random.normal(kk, (pool_pages, ps, cm.h_kv, cm.d))
    v_pool = jax.random.normal(kv, (pool_pages, ps, cm.h_kv, cm.d))
    plans = {
        "codec": plan_mod.pad_plan(plan_mod.build_plan(
            forest, cm, num_lanes, max_q, max_kv)),
        "flash": plan_mod.pad_plan(plan_mod.flash_plan(
            forest, cm, num_lanes, max_q, max_kv)),
    }
    out_ref = registry.get("ref")(q, k_pool, v_pool, plans["codec"])
    rows: Dict[str, Dict] = {}
    for name in backends or registry.names():
        be = registry.get(name)
        plan = plans.get(be.plan_kind, plans["codec"])
        prepared = be.prepare(plan)

        def call():
            return jax.block_until_ready(
                be(q, k_pool, v_pool, plan, prepared=prepared))

        us = timeit(call, repeats=repeats)
        err = float(jnp.abs(call() - out_ref).max())
        rows[name] = dict(us_per_call=us, max_err=err,
                          tasks=plan.num_tasks)
    return rows


def codec_vs_flash(forest: tree_mod.PrefixForest, cm: CostModel,
                   num_lanes: int = 8, max_q: int = 64,
                   max_kv: int = 8192):
    """Modeled makespan + exact IO for the codec plan vs the
    FlashDecoding (per-request) plan on the same forest."""
    plan_mod.assign_dense_pages(forest)
    pc = plan_mod.build_plan(forest, cm, num_lanes, max_q, max_kv)
    pf = plan_mod.flash_plan(forest, cm, num_lanes, max_q, max_kv)
    io_c = forest.codec_io_bytes(cm.h_kv, cm.d)
    io_f = forest.flash_io_bytes(cm.h_kv, cm.d)
    return dict(
        makespan_codec_ms=pc.makespan * 1e3,
        makespan_flash_ms=pf.makespan * 1e3,
        speedup=pf.makespan / max(pc.makespan, 1e-12),
        io_codec_mb=io_c / 1e6,
        io_flash_mb=io_f / 1e6,
        io_reduction=io_f / max(io_c, 1),
        tasks_codec=pc.num_tasks,
        tasks_flash=pf.num_tasks,
        occupancy=pc.stats()["grid_occupancy"],
    )
