"""Paper Fig. 7: end-to-end TPOT, CoDec engine vs the vLLM-analogue
(same engine, FlashDecoding backend).

CPU wall-time on the smoke model (real execution, interpret kernels)
plus the modeled full-scale TPOT decomposition (attention makespan from
the cost model + roofline FFN time) for the paper's Qwen3-4B.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, paper_cost_model, timeit
from repro.configs import get_config, smoke_config
from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import HBM_BW
from repro.models import transformer as T
from repro.serving.engine import DecodeEngine

PAGE = 64


def measured_smoke() -> None:
    cfg = smoke_config("qwen2.5-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 10 + 96))
    prompts = [doc + [200 + 4 * i + j for j in range(4)] for i in range(4)]
    for backend, fused in (("codec-xla", False), ("codec-xla", True),
                           ("flash", False)):
        eng = DecodeEngine(cfg, params, page_size=16, num_pages=1024,
                           backend=backend, max_q=8, fused=fused)
        for p in prompts:
            eng.add_request(p, max_new=6)
        # wall-clock TPOT with a terminal device sync, started after the
        # first step so prefill + cold jit compiles are excluded: on the
        # fused path stats["decode_time"] alone would only cover host
        # dispatch + boundary syncs (async compute surfaces at the block)
        eng.step()
        t0 = time.perf_counter()
        eng.run(6)
        eng.flush_tokens()
        jax.block_until_ready(eng.pool.k)
        steps = eng.stats["steps"] - 1
        tpot_ms = (time.perf_counter() - t0) / max(steps, 1) * 1e3
        emit("fig7_smoke", backend + ("-fused" if fused else ""),
             us_per_call=tpot_ms * 1e3,
             tpot_ms=tpot_ms, steps=eng.stats["steps"],
             plan_s=eng.stats["plan_time"])


def modeled_full() -> None:
    """Full Qwen3-4B TPOT model: attention makespan + memory-bound rest."""
    cfg = get_config("qwen3-4b")
    cm = paper_cost_model(PAGE)
    n_attn = cfg.num_layers
    # non-attention per-step time: stream active params once (memory bound)
    ffn_bytes = cfg.param_count() * 2
    t_rest = ffn_bytes / HBM_BW
    for ctx in (30_000, 60_000, 120_000):
        f = tree_mod.two_level(32, ctx // PAGE * PAGE, 2048, PAGE)
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, 8, 256, 8192)
        pf = plan_mod.flash_plan(f, cm, 8, 256, 8192)
        tpot_c = n_attn * pc.makespan + t_rest
        tpot_f = n_attn * pf.makespan + t_rest
        emit("fig7_model", f"ctx{ctx}",
             tpot_codec_ms=tpot_c * 1e3, tpot_flash_ms=tpot_f * 1e3,
             speedup=tpot_f / tpot_c)


def main() -> None:
    measured_smoke()
    modeled_full()


if __name__ == "__main__":
    main()
