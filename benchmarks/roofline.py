"""Roofline report: reads experiments/dryrun/*.json, prints the per-cell
three-term table (compute / memory / collective seconds, dominant term,
MODEL_FLOPS ratio) and the markdown used by EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(tag: str = "") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        if rec.get("tag", "") != tag:
            continue
        out.append(rec)
    return out


def markdown_table(cells: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | t_compute | t_memory | t_collective "
            "| dominant | useful | per-dev GiB | fits |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]), r["mesh"])
    for r in sorted([c for c in cells if c["status"] == "ok"], key=key):
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['t_compute_s']:.3e} | {ro['t_memory_s']:.3e} "
            f"| {ro['t_collective_s']:.3e} | {ro['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['per_device_bytes'] / 2**30:.2f} "
            f"| {'y' if r['fits_hbm'] else 'n'} |")
    for r in [c for c in cells if c["status"] == "skip"]:
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                    f"| — | — | — | skipped: {r['reason'][:40]} | | | |")
    return "\n".join(rows)


def main() -> None:
    cells = load_cells()
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    for r in ok:
        ro = r["roofline"]
        emit("roofline", f"{r['arch']}|{r['shape']}|{r['mesh']}",
             t_compute_s=ro["t_compute_s"], t_memory_s=ro["t_memory_s"],
             t_collective_s=ro["t_collective_s"],
             dominant=ro["dominant"],
             useful=r["useful_flops_ratio"],
             per_dev_gib=r["per_device_bytes"] / 2**30)
    print(f"\n# cells ok={len(ok)} skip={len(skip)}")
    out = os.path.join(DRYRUN_DIR, "..", "roofline_table.md")
    with open(out, "w") as f:
        f.write(markdown_table(cells) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
