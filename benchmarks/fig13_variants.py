"""Paper Fig. 13: attention variants (MHA/GQA/MQA) and model sizes.

(a) GQA group sweep at fixed q-head count: CoDec's KV-page reuse grows
    with the group size (one KV head's page feeds `group` query rows).
(b) Model-family sweep over the assigned archs' real head layouts.
"""

from __future__ import annotations

from benchmarks.common import bench_backends, codec_vs_flash, emit
from repro.configs import ASSIGNED_ARCHS, PAPER_ARCH, get_config
from repro.core import tree as tree_mod
from repro.core.cost_model import CostModel

PAGE = 64


def main() -> None:
    # (a) GQA sweep: 32 query heads, kv heads in {32 (MHA) .. 1 (MQA)}
    for hkv in (32, 16, 8, 4, 2, 1):
        cm = CostModel(32, hkv, 128, page_size=PAGE)
        f = tree_mod.two_level(32, 120_000 // PAGE * PAGE, 2048, PAGE)
        r = codec_vs_flash(f, cm)
        kind = "MHA" if hkv == 32 else ("MQA" if hkv == 1 else f"GQA{32//hkv}")
        emit("fig13_gqa", f"kv{hkv}_{kind}", **r)

    # (b) real model head layouts (attention archs only)
    for arch in ASSIGNED_ARCHS + [PAPER_ARCH]:
        cfg = get_config(arch)
        if cfg.num_heads == 0:
            emit("fig13_models", arch, skipped=1,
                 note="attention-free (SSM): CoDec inapplicable")
            continue
        cm = CostModel(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                       page_size=PAGE)
        f = tree_mod.two_level(32, 50_000 // PAGE * PAGE, 1024, PAGE)
        r = codec_vs_flash(f, cm)
        emit("fig13_models", arch, **r)

    # (c) executed backend sweep through the registry (small GQA forest;
    #     interpret-mode pallas, so wall time is a smoke signal only)
    cm = CostModel(8, 2, 64, page_size=16)
    f = tree_mod.two_level(8, 8 * 16, 40, 16)
    for name, row in bench_backends(f, cm).items():
        emit("fig13_backends", name, **row)


if __name__ == "__main__":
    main()
