"""Chaos replay: serve_replay traffic under seeded fault schedules.

Replays the serving workload (shared-document prompts, scripted
arrivals, streaming callbacks) through each engine mode — eager,
fused, cached, speculative — twice:

* a **baseline** pass with no faults, recording every token stream;
* a **chaos** pass with a seeded :class:`FaultPlan` (allocator
  exhaustion, failed dispatches, NaN logits, raising callbacks,
  stalls) plus one mid-flight ``cancel()`` and one request that runs
  past its deadline on a fake step-counting clock.

The acceptance bar (ISSUE 8 / DESIGN.md §12):

* **survivor parity** — every request that still finishes ``done``
  streams a token sequence byte-identical to its baseline run;
* **blast-radius** — only NaN / callback victims may end ``failed``;
  alloc / dispatch / stall faults must be absorbed by the degradation
  ladder without touching any stream;
* **no leaks** — after ``shutdown()`` the page pool is empty and the
  invariant self-check passes;
* **quiescence** — every scheduled fault firing was delivered
  (``injector.pending() == 0``), so nothing silently missed its seam.

Writes ``BENCH_chaos.json`` and exits non-zero on any violation, so CI
can run ``--preset smoke`` as a gate.  Wall-clock numbers are
incidental (see benchmarks/common.py); the pass/fail booleans and
fault counters are the signal.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.cache import CachePolicy
from repro.serving.engine import DONE, DecodeEngine
from repro.serving.faults import KINDS, FaultPlan, FaultSpec
from repro.serving.speculation import SpecConfig

PRESETS = {
    # CI-sized: six requests over two shared docs, short generations.
    "smoke": dict(arch="qwen2.5-14b", page_size=16, num_pages=256,
                  doc_len=48, num_docs=2, requests=6, max_new=6,
                  rate=1.0, fault_steps=8, fault_rate=0.08),
    # Deeper soak: more requests, longer tail of fault steps.
    "full": dict(arch="qwen2.5-14b", page_size=16, num_pages=512,
                 doc_len=96, num_docs=3, requests=10, max_new=10,
                 rate=1.0, fault_steps=16, fault_rate=0.15),
}

MODES = ("eager", "fused", "cached", "spec")

# terminal reasons a fault schedule is ALLOWED to produce; anything
# else (e.g. kv_exhausted) means a benign fault escaped its seam
EXPECTED_FAIL = {"nan_logits", "callback_error"}
EXPECTED_STOP = {"cancelled", "deadline", "queue_timeout"}


class StepClock:
    """Deterministic engine clock: one 'second' per engine step."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def build_mix(args):
    """serve_replay-style mix: shared in-vocab docs + unique tails."""
    docs = [np.random.default_rng(1000 + d).integers(
                0, 251, size=args.doc_len).tolist()
            for d in range(args.num_docs)]
    rng = np.random.default_rng(args.seed)
    prompts = []
    for i in range(args.requests):
        tail = [int(t) for t in rng.integers(1, 251, size=4 + (i % 3))]
        prompts.append(docs[i % args.num_docs] + tail)
    return prompts


def build_plan(args, mode) -> FaultPlan:
    """Seeded schedule + a deterministic floor so every kind fires.

    ``alloc`` is handled specially: the allocator seam is only visited
    when pages are actually requested (admission prefills, tail
    growth), so a late-scheduled alloc spec could sit armed forever —
    breaking the quiescence check.  The plan pins a single alloc spec
    at step 0 (guaranteed to meet the first prefill) and keeps the
    seeded draw to the always-visited seams.  Speculative decode pins
    its page working set up front, so the seam is gated off there
    (engine.py ``_alloc_pages``) and alloc is left out entirely.
    """
    kinds = tuple(k for k in KINDS if k != "alloc")
    seeded = FaultPlan.seeded(args.seed, steps=args.fault_steps,
                              rate=args.fault_rate, kinds=kinds,
                              stall_s=0.002)
    floor = [FaultSpec("dispatch", 2, times=2),
             FaultSpec("nan_logits", 4),
             FaultSpec("callback", 3),
             FaultSpec("stall", 5, payload=0.003)]
    if mode != "spec":
        floor.append(FaultSpec("alloc", 0))
    return FaultPlan(list(seeded.specs) + floor)


def make_engine(cfg, params, args, mode, faults=None, clock=None):
    # telemetry=True: counters ride the fake step clock, so the chaos
    # report below can source everything from the metrics registry
    kw = dict(page_size=args.page_size, num_pages=args.num_pages,
              backend="codec-xla", max_q=max(8, args.requests),
              temperature=0.0, faults=faults, nan_guard=True,
              check_every=4, clock=clock, telemetry=True)
    if mode == "fused":
        kw["fused"] = True
    elif mode == "cached":
        kw["cache"] = CachePolicy()
    elif mode == "spec":
        kw["speculative"] = SpecConfig(depth=2, branch=2, max_nodes=3)
    return DecodeEngine(cfg, params, **kw)


def drive(eng, prompts, args, clock, cancels=(), deadline_rid=None,
          max_steps=400):
    """Open-loop scripted replay; returns (streams, reasons).

    ``streams`` is what each request's ``on_token`` callback actually
    saw; ``reasons`` maps rid -> finish_reason from ``on_done``.
    """
    streams: dict = {}
    reasons: dict = {}

    def on_token(rid, tok):
        streams.setdefault(rid, []).append(tok)

    def on_done(rid, reason):
        reasons[rid] = reason

    arrivals = [(int(i / args.rate), p) for i, p in enumerate(prompts)]
    cancel_at = dict(cancels)                      # step -> rid
    rids, i, step = [], 0, 0
    while i < len(arrivals) or eng.has_work():
        while i < len(arrivals) and arrivals[i][0] <= step:
            # half a "second" past submission: at most one decode step
            # lands before the deadline sweep retires it, in every mode
            # (spec commits < max_new tokens per dispatch)
            dl = 0.5 if len(rids) == deadline_rid else None
            rids.append(eng.add_request(
                arrivals[i][1], max_new=args.max_new,
                on_token=on_token, on_done=on_done, deadline_s=dl))
            i += 1
        if step in cancel_at:
            eng.cancel(rids[cancel_at[step]])
        eng.step()
        clock.t += 1.0
        step += 1
        if step > max_steps:
            raise RuntimeError(f"chaos replay did not drain "
                               f"within {max_steps} steps")
    eng.flush_tokens()
    eng._stream_ready()
    eng._notify_done()
    return streams, reasons


def run_mode(cfg, params, args, mode):
    prompts = build_mix(args)
    rec = {"mode": mode, "violations": []}

    def fail(msg):
        rec["violations"].append(msg)
        print(f"  FAIL [{mode}] {msg}", file=sys.stderr)

    # ---- baseline pass: no faults, full streams ---------------------- #
    clk = StepClock()
    eng = make_engine(cfg, params, args, mode, clock=clk)
    t0 = time.perf_counter()
    base_streams, base_reasons = drive(eng, prompts, args, clk)
    rec["baseline_wall_s"] = time.perf_counter() - t0
    if any(v != "done" for v in base_reasons.values()):
        fail(f"baseline pass not clean: {base_reasons}")
    base_left = eng.shutdown()["used_pages"]
    if base_left:
        fail(f"baseline leaked {base_left} pages")

    # ---- chaos pass: seeded faults + cancel + deadline --------------- #
    plan = build_plan(args, mode)
    clock = StepClock()
    eng = make_engine(cfg, params, args, mode, faults=plan, clock=clock)
    # cancel the second-to-last request just after its arrival (spec
    # mode commits several tokens per step, so a later cancel could
    # race completion); the last request gets a deadline it cannot meet
    cancel_rid = args.requests - 2
    cancel_step = int(cancel_rid / args.rate) + (0 if mode == "spec"
                                                 else 2)
    t0 = time.perf_counter()
    streams, reasons = drive(eng, prompts, args, clock,
                             cancels=[(cancel_step, cancel_rid)],
                             deadline_rid=args.requests - 1)
    rec["chaos_wall_s"] = time.perf_counter() - t0
    st = eng.stats
    rec["faults_fired"] = dict(eng.injector.fired)
    rec["faults_pending"] = eng.injector.pending()
    rec["outcomes"] = {r: reasons.get(r, eng.requests[r].finish_reason)
                       for r in sorted(eng.requests)}
    # reported counters come from the metrics registry, not the raw
    # stats dict — publish_metrics() syncs and returns it
    reg = eng.publish_metrics()
    rec["stats"] = {k: reg[k].value for k in (
        "faults_injected", "dispatch_failures", "dispatch_recoveries",
        "nan_rows", "callback_errors", "requests_cancelled",
        "requests_timed_out", "requests_failed", "invariant_checks",
        "preemptions")}

    # survivor parity: done requests stream byte-identical to baseline
    survivors = [r for r, q in eng.requests.items() if q.state == DONE]
    rec["survivors"] = len(survivors)
    for r in survivors:
        if streams.get(r) != base_streams.get(r):
            fail(f"survivor {r} diverged: {streams.get(r)} != "
                 f"{base_streams.get(r)}")
        if streams.get(r) != eng.requests[r].generated:
            fail(f"survivor {r} stream != generated")
    rec["survivor_parity"] = not any(
        "diverged" in v or "generated" in v for v in rec["violations"])

    # blast radius: non-survivors must be fault victims, never
    # collateral of alloc/dispatch/stall (those are absorbed)
    for r, q in eng.requests.items():
        if q.state == DONE:
            continue
        reason = q.finish_reason
        if q.state == "failed" and reason not in EXPECTED_FAIL:
            fail(f"request {r} failed for unexpected reason {reason!r}")
        if q.state in ("cancelled", "timed_out") \
                and reason not in EXPECTED_STOP:
            fail(f"request {r} stopped for unexpected reason {reason!r}")
    # the scheduled cancel / deadline victims must leave through their
    # lane — unless a fault legitimately claimed them first
    all_rids = sorted(eng.requests)
    for rid, want in ((all_rids[cancel_rid], "cancelled"),
                      (all_rids[-1], "timed_out")):
        q = eng.requests[rid]
        if q.state != want and not (q.state == "failed"
                                    and q.finish_reason in EXPECTED_FAIL):
            fail(f"{want} victim {rid} ended {q.state}"
                 f"/{q.finish_reason} instead")

    # degradation ladder: every injected dispatch failure recovered
    if eng.injector.fired["dispatch"] != st["dispatch_recoveries"]:
        fail(f"dispatch faults {eng.injector.fired['dispatch']} != "
             f"recoveries {st['dispatch_recoveries']}")

    # quiescence: the whole schedule was delivered
    if rec["faults_pending"]:
        fail(f"{rec['faults_pending']} fault firings never delivered")

    # self-check + leak check on the wreckage
    try:
        eng.check()
    except Exception as e:                    # EngineInvariantError
        fail(f"post-chaos invariant check: {e}")
    leaked = eng.shutdown()["used_pages"]
    rec["leaked_pages"] = leaked
    if leaked:
        fail(f"chaos pass leaked {leaked} pages")

    rec["ok"] = not rec["violations"]
    print(f"[{mode}] {'ok' if rec['ok'] else 'FAIL'}: "
          f"{rec['stats']['faults_injected']:.0f} faults "
          f"({rec['faults_fired']}), survivors "
          f"{rec['survivors']}/{args.requests}, outcomes "
          f"{rec['outcomes']}, leaked {leaked} pages")
    return rec


def run_benign(cfg, params, args):
    """Disruption-free kinds only: alloc/dispatch/stall must leave
    every stream untouched — all requests finish ``done`` and match
    the fault-free baseline byte-for-byte."""
    prompts = build_mix(args)
    rec = {"mode": "eager-benign", "violations": []}
    clk = StepClock()
    eng = make_engine(cfg, params, args, "eager", clock=clk)
    base_streams, _ = drive(eng, prompts, args, clk)
    eng.shutdown()

    plan = FaultPlan([FaultSpec("alloc", 0), FaultSpec("alloc", 2),
                      FaultSpec("dispatch", 1, times=2),
                      FaultSpec("dispatch", 4),
                      FaultSpec("stall", 3, payload=0.002)])
    clk = StepClock()
    eng = make_engine(cfg, params, args, "eager", faults=plan,
                      clock=clk)
    streams, reasons = drive(eng, prompts, args, clk)
    if streams != base_streams:
        rec["violations"].append("benign faults perturbed a stream")
    if any(v != "done" for v in reasons.values()):
        rec["violations"].append(f"benign faults ended a request "
                                 f"early: {reasons}")
    rec["faults_fired"] = dict(eng.injector.fired)
    rec["faults_pending"] = eng.injector.pending()
    if rec["faults_pending"]:
        rec["violations"].append("benign schedule not fully delivered")
    rec["leaked_pages"] = eng.shutdown()["used_pages"]
    if rec["leaked_pages"]:
        rec["violations"].append(f"leaked {rec['leaked_pages']} pages")
    rec["ok"] = not rec["violations"]
    print(f"[eager-benign] {'ok' if rec['ok'] else 'FAIL'}: "
          f"{rec['faults_fired']} absorbed, streams identical: "
          f"{streams == base_streams}")
    for v in rec["violations"]:
        print(f"  FAIL [eager-benign] {v}", file=sys.stderr)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--modes", default=",".join(MODES),
                    help="comma-separated subset of " + ",".join(MODES))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    for k, v in PRESETS[args.preset].items():
        setattr(args, k, v)

    cfg = smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    records = [run_benign(cfg, params, args)]
    for mode in args.modes.split(","):
        records.append(run_mode(cfg, params, args, mode))

    ok = all(r["ok"] for r in records)
    result = {"preset": args.preset, "seed": args.seed, "ok": ok,
              "modes": records}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"# wrote {args.out}: "
          f"{'all modes ok' if ok else 'VIOLATIONS (see stderr)'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
