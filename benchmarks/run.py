"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run [module ...]`` — runs all by default and
prints ``bench,name,us_per_call,derived`` CSV lines.

``--preset smoke`` runs the CI-sized decode-trajectory benchmark only
(fused vs eager TPOT) and writes the ``BENCH_decode.json`` perf-baseline
artifact.
"""

from __future__ import annotations

import importlib
import sys
import time
import traceback

MODULES = [
    "table2_cost_profile",   # Table 2
    "fig5_exec_time",        # Fig. 5
    "fig6_memory_access",    # Fig. 6
    "fig7_e2e_tpot",         # Fig. 7
    "fig8_multilevel",       # Fig. 8
    "fig9_ablation",         # Fig. 9
    "fig10_granularity",     # Fig. 10
    "fig11_overhead",        # Fig. 11
    "fig12_hardware",        # Fig. 12 (hardware sweep analogue)
    "fig13_variants",        # Fig. 13
    "roofline",              # EXPERIMENTS.md §Roofline source
    "decode_trajectory",     # fused-vs-eager TPOT baseline artifact
    "shard_scaling",         # device-count sweep -> BENCH_shard.json
]

PRESETS = {
    # smoke: the e2e decode baseline CI regresses against
    "smoke": ["decode_trajectory"],
}


def main() -> int:
    args = sys.argv[1:]
    if args[:1] == ["--preset"]:
        if len(args) < 2 or args[1] not in PRESETS:
            print(f"usage: --preset {{{','.join(PRESETS)}}}")
            return 2
        mods = PRESETS[args[1]] + args[2:]
    else:
        mods = args or MODULES
    print("bench,name,us_per_call,derived")
    failed = []
    for name in mods:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        return 1
    print("# all benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
