"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run [module ...]`` — runs all by default and
prints ``bench,name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import importlib
import sys
import time
import traceback

MODULES = [
    "table2_cost_profile",   # Table 2
    "fig5_exec_time",        # Fig. 5
    "fig6_memory_access",    # Fig. 6
    "fig7_e2e_tpot",         # Fig. 7
    "fig8_multilevel",       # Fig. 8
    "fig9_ablation",         # Fig. 9
    "fig10_granularity",     # Fig. 10
    "fig11_overhead",        # Fig. 11
    "fig12_hardware",        # Fig. 12 (hardware sweep analogue)
    "fig13_variants",        # Fig. 13
    "roofline",              # EXPERIMENTS.md §Roofline source
]


def main() -> int:
    mods = sys.argv[1:] or MODULES
    print("bench,name,us_per_call,derived")
    failed = []
    for name in mods:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        return 1
    print("# all benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
