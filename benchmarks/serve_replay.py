"""Serving replay benchmark: request arrivals against the live engine.

Replays a trace of requests — Poisson or scripted arrivals over a
prompt mix that shares system prompts — through one persistent
``DecodeEngine`` with the cross-request prefix cache enabled, and
measures per-request TTFT (submit -> first streamed token) and TPOT
(mean inter-token gap) via the streaming callbacks.

Two passes run through the SAME engine: the cold pass starts from an
empty cache, the warm pass re-uses the documents the cold pass left
resident (new per-request tails, so only the shared prefixes can hit).
``BENCH_serve.json`` records p50/p99 TTFT and TPOT for both passes plus
the warm-pass cache counters, giving CI a cold-vs-warm baseline.  A
third scenario (``burst``) replays a cold shared-prompt burst — N
requests over one uncached doc at step 0 — with cascade prefill
(DESIGN.md §14) on vs off and records both TTFT distributions; on the
smoke preset CI asserts cascade is no worse than sequential.

All reported numbers come from the engine's metrics registry
(docs/OBSERVABILITY.md): each pass snapshots the registry and takes a
reader-owned delta, so two readers at different cadences can never
double-count.  ``--trace-out``/``--metrics-out`` export the Chrome
trace and the registry snapshot; ``telemetry_overhead`` measures TPOT
with telemetry on vs off (asserted <3% on the smoke preset).

Wall-clock caveat (see benchmarks/common.py): absolute latencies on
this CPU container are not the deliverable; the cold/warm *ratio* and
the hit-rate are the signal.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import metrics as metrics_mod
from repro.models import transformer as T
from repro.serving.cache import CachePolicy
from repro.serving.engine import DecodeEngine
from repro.serving.telemetry import Telemetry

PRESETS = {
    # CI-sized: two shared docs, six requests per pass, tiny tails.
    "smoke": dict(arch="qwen2.5-14b", backend="codec-xla", page_size=16,
                  num_pages=512, doc_len=64, num_docs=2, requests=6,
                  max_new=4, arrivals="scripted", rate=2.0),
    # Longer mix: three docs, Poisson arrivals, deeper generations.
    "full": dict(arch="qwen2.5-14b", backend="codec-xla", page_size=16,
                 num_pages=2048, doc_len=192, num_docs=3, requests=16,
                 max_new=16, arrivals="poisson", rate=1.5),
}


def build_mix(args, rng, pass_no):
    """Prompts over shared system prompts + per-request unique tails.

    Token ids must fit the smoke vocab (the engine validates prompts),
    so each doc draws from its own seeded stream — docs stay distinct
    from each other and stable across passes/pass_no."""
    docs = [np.random.default_rng(1000 + d).integers(
                0, 251, size=args.doc_len).tolist()
            for d in range(args.num_docs)]
    prompts = []
    for i in range(args.requests):
        doc = docs[i % args.num_docs]
        tail = [int(t) for t in
                rng.integers(1, 251, size=4 + (i % 3))]
        prompts.append(doc + tail)
    return prompts


def build_schedule(args, rng, prompts):
    """Arrival step for each prompt.

    * ``scripted``: a fixed staircase — one request per ``1/rate``
      steps, deterministic and preset-reproducible.
    * ``poisson``: exponential inter-arrival gaps at ``rate``
      requests/step (classic open-loop replay).
    """
    n = len(prompts)
    if args.arrivals == "scripted":
        steps = [int(i / args.rate) for i in range(n)]
    else:
        gaps = rng.exponential(scale=1.0 / args.rate, size=n)
        steps = np.floor(np.cumsum(gaps)).astype(int).tolist()
    return list(zip(steps, prompts))


def replay(eng, schedule, max_new, max_steps=100_000):
    """Step-driven open-loop replay; returns per-request timing records."""
    recs = []
    pending = sorted(schedule, key=lambda x: x[0])
    i, step = 0, 0
    while i < len(pending) or eng.has_work():
        while i < len(pending) and pending[i][0] <= step:
            rec = {"submit": time.perf_counter(), "toks": []}

            def cb(rid, tok, rec=rec):
                now = time.perf_counter()
                if not rec["toks"]:
                    rec["first"] = now
                rec["last"] = now
                rec["toks"].append(tok)

            eng.add_request(pending[i][1], max_new=max_new, on_token=cb)
            recs.append(rec)
            i += 1
        eng.step()
        step += 1
        if step > max_steps:
            raise RuntimeError("replay did not drain")
    eng.flush_tokens()
    eng._stream_ready()
    return recs


def check_streams(recs, max_new):
    assert all(len(r["toks"]) == max_new for r in recs), \
        "every request must stream its full generation"


def registry_summary(d, eng):
    """Per-pass summary from a metrics-registry snapshot delta.

    The registry is the single source: TTFT/TPOT from the histogram
    deltas (submit -> token host-visible, on the engine clock), cache
    counters from the synced cache_* counters."""
    q = lambda h, p: 1e3 * metrics_mod.hist_quantile(h, p)
    hits = d["cache_hits"]["value"]
    misses = d["cache_misses"]["value"]
    return {
        "requests": int(d["requests_done"]["value"]),
        "tokens": int(d["tokens_generated"]["value"]),
        "ttft_ms": {"p50": q(d["ttft_s"], 0.50),
                    "p99": q(d["ttft_s"], 0.99)},
        "tpot_ms": {"p50": q(d["tpot_s"], 0.50),
                    "p99": q(d["tpot_s"], 0.99)},
        "cache": {
            "hits": hits, "misses": misses,
            "hit_tokens": d["cache_hit_tokens"]["value"],
            "hit_rate": hits / max(hits + misses, 1),
            "evicted_nodes": d["cache_evicted_nodes"]["value"],
            "resident_pages": eng.cache.resident_pages(),
        },
    }


def mean_tpot_ms(recs):
    vals = [(r["last"] - r["first"]) / (len(r["toks"]) - 1) * 1e3
            for r in recs if len(r["toks"]) > 1]
    return float(np.mean(vals)) if vals else 0.0


def measure_overhead(args, cfg, params, schedule, reps):
    """TPOT with telemetry on vs off: fresh engine per rep, identical
    schedule, min-of-reps each way to squeeze out scheduler noise.
    Also asserts the token streams are byte-identical on vs off."""

    def one(enabled):
        best, streams = float("inf"), None
        for _ in range(reps):
            eng = DecodeEngine(
                cfg, params, page_size=args.page_size,
                num_pages=args.num_pages, backend=args.backend,
                max_q=max(8, args.requests), temperature=0.0,
                fused=args.fused,
                cache=CachePolicy(ttl_steps=args.cache_ttl,
                                  max_pages=args.cache_pages),
                telemetry=Telemetry() if enabled else None)
            recs = replay(eng, schedule, args.max_new)
            check_streams(recs, args.max_new)
            best = min(best, mean_tpot_ms(recs))
            streams = [r["toks"] for r in recs]
        return best, streams

    off, streams_off = one(False)   # off first: warms any jit caches
    on, streams_on = one(True)
    assert streams_on == streams_off, \
        "telemetry must not change token streams"
    return {"reps": reps, "tpot_off_ms": off, "tpot_on_ms": on,
            "overhead_frac": on / max(off, 1e-9) - 1.0}


def measure_burst(args, cfg, params, reps):
    """Cold shared-prompt burst: cascade vs sequential prefill TTFT.

    N requests over ONE uncached shared doc arrive at step 0 behind a
    decoy head whose private prompt absorbs the first chunk budgets —
    so the doc is still cold when the burst's head admits and (with
    ``cascade=True``) pulls its partners out of the wait queue.  Fresh
    engine per rep and mode (no cross-request cache: the point is the
    *uncached* path), chunked prefill at one page per chunk, min-of-reps
    per mode.  Streams must be byte-identical across modes, and the
    cascade pass must charge the shared span ~once (prefill-token
    counters from the metrics registry), not once per request.
    """
    n = args.burst_requests
    doc = np.random.default_rng(2000).integers(
        0, 251, size=args.doc_len).tolist()
    decoy = np.random.default_rng(2001).integers(
        0, 251, size=args.doc_len).tolist() + [251, 252]
    prompts = [decoy] + [doc + [1 + 5 * i + j for j in range(3)]
                         for i in range(n)]
    schedule = [(0, p) for p in prompts]
    unique_tokens = sum(len(p) for p in (decoy, doc)) + 3 * n

    def one(cascade):
        best, streams, counters = None, None, None
        for _ in range(reps):
            eng = DecodeEngine(
                cfg, params, page_size=args.page_size,
                num_pages=args.num_pages, backend=args.backend,
                max_q=max(8, n + 1), temperature=0.0, fused=args.fused,
                prefill_chunk=args.page_size, cascade=cascade,
                telemetry=Telemetry())
            recs = replay(eng, schedule, args.max_new)
            check_streams(recs, args.max_new)
            # TTFT over the burst members (the decoy is scaffolding)
            ttfts = [1e3 * (r["first"] - r["submit"]) for r in recs[1:]]
            cur = {"p50": float(np.percentile(ttfts, 50)),
                   "p99": float(np.percentile(ttfts, 99))}
            if best is None or cur["p50"] < best["p50"]:
                best = cur
            streams = [r["toks"] for r in recs]
            snap = eng.publish_metrics().snapshot()
            counters = {k: int(snap[k]["value"]) for k in
                        ("prefill_tokens", "cascade_groups",
                         "cascade_shared_tokens", "cascade_batches")}
        return best, streams, counters

    seq, streams_seq, _ = one(False)   # first: warms shared jit shapes
    cas, streams_cas, counters = one(True)
    assert streams_cas == streams_seq, \
        "cascade prefill must not change token streams"
    # shared span charged ~once: a cascaded cold burst prefills about
    # the unique token count, never N x the shared doc (slack: one
    # final-logit recompute per member + one chunk of group ramp-up)
    assert counters["cascade_shared_tokens"] > 0, counters
    assert counters["prefill_tokens"] <= \
        unique_tokens + n + args.page_size, counters
    return {
        "requests": n, "doc_len": args.doc_len,
        "unique_tokens": unique_tokens, "reps": reps,
        "ttft_ms": {"sequential": seq, "cascade": cas},
        "cascade_counters": counters,
        "ttft_p50_speedup": seq["p50"] / max(cas["p50"], 1e-9),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--arrivals", choices=("poisson", "scripted"))
    ap.add_argument("--rate", type=float, help="arrivals per engine step")
    ap.add_argument("--requests", type=int)
    ap.add_argument("--doc-len", type=int)
    ap.add_argument("--num-docs", type=int)
    ap.add_argument("--max-new", type=int)
    ap.add_argument("--backend")
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--cache-ttl", type=int, default=None)
    ap.add_argument("--cache-pages", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome trace-event JSON "
                         "(Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry snapshot JSON "
                         "(schema codec-metrics/1, plus a 'passes' "
                         "section with the per-pass summaries)")
    ap.add_argument("--profile-every", type=int, default=0,
                    help="sampled step profiling period (0 = off)")
    ap.add_argument("--overhead-reps", type=int, default=3,
                    help="reps per mode for the telemetry-overhead "
                         "check (0 = skip)")
    ap.add_argument("--burst-reps", type=int, default=3,
                    help="reps per mode for the cold shared-prompt "
                         "burst (cascade vs sequential; 0 = skip)")
    ap.add_argument("--burst-requests", type=int, default=4,
                    help="burst members sharing the cold doc")
    args = ap.parse_args(argv)
    for k, v in PRESETS[args.preset].items():
        if getattr(args, k, None) is None:
            setattr(args, k, v)

    cfg = smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    policy = CachePolicy(ttl_steps=args.cache_ttl,
                         max_pages=args.cache_pages)
    telemetry = Telemetry(profile_every=args.profile_every)
    eng = DecodeEngine(cfg, params, page_size=args.page_size,
                       num_pages=args.num_pages, backend=args.backend,
                       max_q=max(8, args.requests), temperature=0.0,
                       fused=args.fused, cache=policy,
                       telemetry=telemetry)

    result = {"preset": args.preset, "arch": args.arch,
              "backend": args.backend, "arrivals": args.arrivals,
              "config": dict(page_size=args.page_size,
                             num_pages=args.num_pages,
                             doc_len=args.doc_len, num_docs=args.num_docs,
                             requests=args.requests, max_new=args.max_new,
                             rate=args.rate, seed=args.seed)}
    cold_schedule = None
    for pass_no, name in enumerate(("cold", "warm")):
        prompts = build_mix(args, rng, pass_no)
        schedule = build_schedule(args, rng, prompts)
        if pass_no == 0:
            cold_schedule = schedule
        prev = eng.publish_metrics().snapshot()
        t0 = time.perf_counter()
        recs = replay(eng, schedule, args.max_new)
        wall = time.perf_counter() - t0
        check_streams(recs, args.max_new)
        d = metrics_mod.delta(eng.publish_metrics().snapshot(), prev)
        summ = registry_summary(d, eng)
        summ["wall_s"] = wall
        result[name] = summ
        print(f"{name}: ttft p50 {summ['ttft_ms']['p50']:.1f} ms "
              f"p99 {summ['ttft_ms']['p99']:.1f} ms | "
              f"tpot p50 {summ['tpot_ms']['p50']:.1f} ms | "
              f"hit rate {summ['cache']['hit_rate']:.0%} "
              f"({summ['cache']['hit_tokens']} cached tokens)")
        for r in list(eng.requests):
            eng.release(r)

    result["ttft_p50_speedup"] = (result["cold"]["ttft_ms"]["p50"]
                                  / max(result["warm"]["ttft_ms"]["p50"],
                                        1e-9))
    if args.overhead_reps > 0:
        oh = measure_overhead(args, cfg, params, cold_schedule,
                              args.overhead_reps)
        result["telemetry_overhead"] = oh
        print(f"telemetry overhead: tpot {oh['tpot_on_ms']:.2f} ms on / "
              f"{oh['tpot_off_ms']:.2f} ms off "
              f"({100 * oh['overhead_frac']:+.1f}%)")
        if args.preset == "smoke":
            limit = float(os.environ.get("BENCH_OVERHEAD_LIMIT", "0.03"))
            assert oh["overhead_frac"] < limit, \
                (f"telemetry overhead {oh['overhead_frac']:.1%} exceeds "
                 f"{limit:.0%} budget")
    if args.burst_reps > 0:
        bw = measure_burst(args, cfg, params, args.burst_reps)
        result["burst"] = bw
        print(f"burst: cascade ttft p50 "
              f"{bw['ttft_ms']['cascade']['p50']:.1f} ms vs sequential "
              f"{bw['ttft_ms']['sequential']['p50']:.1f} ms "
              f"({bw['ttft_p50_speedup']:.2f}x, "
              f"{bw['cascade_counters']['cascade_shared_tokens']} shared "
              f"tokens reused)")
        if args.preset == "smoke":
            limit = float(os.environ.get("BENCH_BURST_LIMIT", "1.05"))
            p50c = bw["ttft_ms"]["cascade"]["p50"]
            p50s = bw["ttft_ms"]["sequential"]["p50"]
            assert p50c <= limit * p50s, \
                (f"cascade burst TTFT p50 {p50c:.1f} ms worse than "
                 f"sequential {p50s:.1f} ms (limit {limit:.2f}x)")
    if args.trace_out:
        telemetry.export_trace(args.trace_out)
        print(f"# wrote {args.trace_out}: "
              f"{len(telemetry.trace_events())} trace events")
    if args.metrics_out:
        eng.export_metrics(args.metrics_out, extra={"passes": {
            n: {k: result[n][k] for k in
                ("ttft_ms", "tpot_ms", "cache", "requests", "tokens")}
            for n in ("cold", "warm")}})
        print(f"# wrote {args.metrics_out}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"# wrote {args.out}: warm/cold ttft p50 "
          f"{result['warm']['ttft_ms']['p50']:.1f}/"
          f"{result['cold']['ttft_ms']['p50']:.1f} ms "
          f"({result['ttft_p50_speedup']:.1f}x)")


if __name__ == "__main__":
    main()
