"""Serving replay benchmark: request arrivals against the live engine.

Replays a trace of requests — Poisson or scripted arrivals over a
prompt mix that shares system prompts — through one persistent
``DecodeEngine`` with the cross-request prefix cache enabled, and
measures per-request TTFT (submit -> first streamed token) and TPOT
(mean inter-token gap) via the streaming callbacks.

Two passes run through the SAME engine: the cold pass starts from an
empty cache, the warm pass re-uses the documents the cold pass left
resident (new per-request tails, so only the shared prefixes can hit).
``BENCH_serve.json`` records p50/p99 TTFT and TPOT for both passes plus
the warm-pass cache counters, giving CI a cold-vs-warm baseline.

Wall-clock caveat (see benchmarks/common.py): absolute latencies on
this CPU container are not the deliverable; the cold/warm *ratio* and
the hit-rate are the signal.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serving.cache import CachePolicy
from repro.serving.engine import DecodeEngine

PRESETS = {
    # CI-sized: two shared docs, six requests per pass, tiny tails.
    "smoke": dict(arch="qwen2.5-14b", backend="codec-xla", page_size=16,
                  num_pages=512, doc_len=64, num_docs=2, requests=6,
                  max_new=4, arrivals="scripted", rate=2.0),
    # Longer mix: three docs, Poisson arrivals, deeper generations.
    "full": dict(arch="qwen2.5-14b", backend="codec-xla", page_size=16,
                 num_pages=2048, doc_len=192, num_docs=3, requests=16,
                 max_new=16, arrivals="poisson", rate=1.5),
}


def build_mix(args, rng, pass_no):
    """Prompts over shared system prompts + per-request unique tails.

    Token ids must fit the smoke vocab (the engine validates prompts),
    so each doc draws from its own seeded stream — docs stay distinct
    from each other and stable across passes/pass_no."""
    docs = [np.random.default_rng(1000 + d).integers(
                0, 251, size=args.doc_len).tolist()
            for d in range(args.num_docs)]
    prompts = []
    for i in range(args.requests):
        doc = docs[i % args.num_docs]
        tail = [int(t) for t in
                rng.integers(1, 251, size=4 + (i % 3))]
        prompts.append(doc + tail)
    return prompts


def build_schedule(args, rng, prompts):
    """Arrival step for each prompt.

    * ``scripted``: a fixed staircase — one request per ``1/rate``
      steps, deterministic and preset-reproducible.
    * ``poisson``: exponential inter-arrival gaps at ``rate``
      requests/step (classic open-loop replay).
    """
    n = len(prompts)
    if args.arrivals == "scripted":
        steps = [int(i / args.rate) for i in range(n)]
    else:
        gaps = rng.exponential(scale=1.0 / args.rate, size=n)
        steps = np.floor(np.cumsum(gaps)).astype(int).tolist()
    return list(zip(steps, prompts))


def replay(eng, schedule, max_new, max_steps=100_000):
    """Step-driven open-loop replay; returns per-request timing records."""
    recs = []
    pending = sorted(schedule, key=lambda x: x[0])
    i, step = 0, 0
    while i < len(pending) or eng.has_work():
        while i < len(pending) and pending[i][0] <= step:
            rec = {"submit": time.perf_counter(), "toks": []}

            def cb(rid, tok, rec=rec):
                now = time.perf_counter()
                if not rec["toks"]:
                    rec["first"] = now
                rec["last"] = now
                rec["toks"].append(tok)

            eng.add_request(pending[i][1], max_new=max_new, on_token=cb)
            recs.append(rec)
            i += 1
        eng.step()
        step += 1
        if step > max_steps:
            raise RuntimeError("replay did not drain")
    eng.flush_tokens()
    eng._stream_ready()
    return recs


def summarize(recs, max_new):
    ttft = np.asarray([(r["first"] - r["submit"]) * 1e3 for r in recs])
    tpot = np.asarray([(r["last"] - r["first"]) / (len(r["toks"]) - 1)
                       * 1e3 for r in recs if len(r["toks"]) > 1])
    assert all(len(r["toks"]) == max_new for r in recs), \
        "every request must stream its full generation"
    pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
    return {
        "requests": len(recs),
        "ttft_ms": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
        "tpot_ms": {"p50": pct(tpot, 50), "p99": pct(tpot, 99)},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    ap.add_argument("--arrivals", choices=("poisson", "scripted"))
    ap.add_argument("--rate", type=float, help="arrivals per engine step")
    ap.add_argument("--requests", type=int)
    ap.add_argument("--doc-len", type=int)
    ap.add_argument("--num-docs", type=int)
    ap.add_argument("--max-new", type=int)
    ap.add_argument("--backend")
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--cache-ttl", type=int, default=None)
    ap.add_argument("--cache-pages", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    for k, v in PRESETS[args.preset].items():
        if getattr(args, k, None) is None:
            setattr(args, k, v)

    cfg = smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    policy = CachePolicy(ttl_steps=args.cache_ttl,
                         max_pages=args.cache_pages)
    eng = DecodeEngine(cfg, params, page_size=args.page_size,
                       num_pages=args.num_pages, backend=args.backend,
                       max_q=max(8, args.requests), temperature=0.0,
                       fused=args.fused, cache=policy)

    result = {"preset": args.preset, "arch": args.arch,
              "backend": args.backend, "arrivals": args.arrivals,
              "config": dict(page_size=args.page_size,
                             num_pages=args.num_pages,
                             doc_len=args.doc_len, num_docs=args.num_docs,
                             requests=args.requests, max_new=args.max_new,
                             rate=args.rate, seed=args.seed)}
    for pass_no, name in enumerate(("cold", "warm")):
        prompts = build_mix(args, rng, pass_no)
        schedule = build_schedule(args, rng, prompts)
        snap = dict(eng.cache.stats)
        t0 = time.perf_counter()
        recs = replay(eng, schedule, args.max_new)
        wall = time.perf_counter() - t0
        summ = summarize(recs, args.max_new)
        summ["wall_s"] = wall
        d = {k: eng.cache.stats[k] - snap[k] for k in snap}
        summ["cache"] = {
            "hits": d["hits"], "misses": d["misses"],
            "hit_tokens": d["hit_tokens"],
            "hit_rate": d["hits"] / max(d["hits"] + d["misses"], 1),
            "evicted_nodes": d["evicted_nodes"],
            "resident_pages": eng.cache.resident_pages(),
        }
        result[name] = summ
        print(f"{name}: ttft p50 {summ['ttft_ms']['p50']:.1f} ms "
              f"p99 {summ['ttft_ms']['p99']:.1f} ms | "
              f"tpot p50 {summ['tpot_ms']['p50']:.1f} ms | "
              f"hit rate {summ['cache']['hit_rate']:.0%} "
              f"({d['hit_tokens']} cached tokens)")
        for r in list(eng.requests):
            eng.release(r)

    result["ttft_p50_speedup"] = (result["cold"]["ttft_ms"]["p50"]
                                  / max(result["warm"]["ttft_ms"]["p50"],
                                        1e-9))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"# wrote {args.out}: warm/cold ttft p50 "
          f"{result['warm']['ttft_ms']['p50']:.1f}/"
          f"{result['cold']['ttft_ms']['p50']:.1f} ms "
          f"({result['ttft_p50_speedup']:.1f}x)")


if __name__ == "__main__":
    main()
