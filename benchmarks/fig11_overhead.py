"""Paper Fig. 11: CPU overhead of computing the division plan vs batch.

Measures the real wall time of cost estimation + division + LPT + plan
array construction (this is genuinely a CPU activity, so wall time here
IS the deliverable even on this container), and the amortized per-step
cost under the engine's plan-reuse policy.
"""

from __future__ import annotations

from benchmarks.common import emit, paper_cost_model, timeit
from repro.core import plan as plan_mod, tree as tree_mod

PAGE = 64


def main() -> None:
    cm = paper_cost_model(PAGE)
    for bs in (4, 8, 16, 32, 64, 128):
        f = tree_mod.two_level(bs, 120_000 // PAGE * PAGE, 2048, PAGE)
        plan_mod.assign_dense_pages(f)
        us = timeit(lambda: plan_mod.build_plan(f, cm, 8, 256, 8192),
                    repeats=3)
        emit("fig11", f"bs{bs}", us_per_call=us,
             plan_ms=us / 1e3,
             amortized_ms=us / 1e3 / 16)   # plan reused ~16 decode steps


if __name__ == "__main__":
    main()
