"""Paper Fig. 12 analogue: hardware sweep.

The paper sweeps five GPUs; the TPU target has no card zoo, so we sweep
the roofline constants (peak FLOP/s, HBM bandwidth) across accelerator
classes and report the modeled CoDec-vs-FlashDecoding speedup on the
same 50k-context workload — reproducing the paper's observation that
the win GROWS as memory bandwidth shrinks (decode attention is
bandwidth-bound, and CoDec removes bandwidth).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.cost_model import CostModel, HardwareSpec

PAGE = 64

HW = {  # (peak FLOP/s, HBM B/s) — public datasheet numbers
    "tpu_v5e": (197e12, 819e9),
    "tpu_v5p": (459e12, 2765e9),
    "h800-like": (990e12, 3350e9),
    "a100-like": (312e12, 1555e9),
    "a6000-like": (155e12, 768e9),
    "4090-like": (330e12, 1008e9),
}


def main() -> None:
    f0 = tree_mod.two_level(32, 50_000 // PAGE * PAGE, 2048, PAGE)
    for name, (flops, bw) in HW.items():
        cm = CostModel(32, 8, 128, page_size=PAGE,
                       hw=HardwareSpec(peak_flops=flops, hbm_bw=bw))
        f = tree_mod.two_level(32, 50_000 // PAGE * PAGE, 2048, PAGE)
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, 8, 256, 8192)
        pf = plan_mod.flash_plan(f, cm, 8, 256, 8192)
        emit("fig12", name,
             codec_ms=pc.makespan * 1e3, flash_ms=pf.makespan * 1e3,
             speedup=pf.makespan / max(pc.makespan, 1e-12),
             hbm_gbps=bw / 1e9)


if __name__ == "__main__":
    main()
