"""Paper Fig. 9: ablation — prefix tree / partitioning / parallel reduction.

Configurations (cumulative, as in the paper):
  base        : per-request plan, no division, single lane
  +tree       : prefix-shared tasks, no division, single-lane scheduling
  +partition  : + adaptive KV division (but naive round-robin lanes)
  +parallel   : + LPT multi-lane scheduling and flattened reduction (full)

Workloads: balanced full binary tree and unbalanced degenerate tree,
both ~200k max context (the paper's setup).
"""

from __future__ import annotations

from benchmarks.common import emit, paper_cost_model
from repro.core import plan as plan_mod, tree as tree_mod
from repro.core.scheduler import (Schedule, SubTask, TaskSpec,
                                  divide_and_schedule, lpt, naive_divide)

PAGE = 64
LANES = 8


def _tasks(forest):
    return [TaskSpec(n.id, len(n.requests), n.length)
            for n in forest.real_nodes()]


def _flash_tasks(forest):
    out = []
    for n in forest.real_nodes():
        for qi in range(len(n.requests)):
            out.append(TaskSpec(n.id * 10000 + qi, 1, n.length))
    return out


def _roundrobin(subs, lanes):
    lane_cost = [0.0] * lanes
    for i, s in enumerate(subs):
        lane_cost[i % lanes] += s.cost
    return max(lane_cost)


def main() -> None:
    cm = paper_cost_model(PAGE)
    workloads = {
        "balanced": tree_mod.full_kary(6, 2, 200_000 // 63 // PAGE * PAGE,
                                       PAGE),
        "degenerate": tree_mod.degenerate(12, 200_000 // 23 // PAGE * PAGE,
                                          PAGE),
    }
    for wname, f in workloads.items():
        base_subs = [SubTask(t.node_id, 0, t.n_q, 0, t.n, cm(t.n_q, t.n))
                     for t in _flash_tasks(f)]
        base = sum(s.cost for s in base_subs)          # sequential baseline

        tree_subs = [SubTask(t.node_id, 0, t.n_q, 0, t.n, cm(t.n_q, t.n))
                     for t in _tasks(f)]
        tree_only = sum(s.cost for s in tree_subs)

        sched = divide_and_schedule(_tasks(f), cm, LANES, PAGE,
                                    max_kv_per_task=8192)
        part_rr = _roundrobin(sched.subtasks, LANES)   # division, naive sched
        full = sched.makespan                          # division + LPT

        emit("fig9", wname,
             base_ms=base * 1e3,
             tree_ms=tree_only * 1e3,
             partition_ms=part_rr * 1e3,
             full_ms=full * 1e3,
             total_speedup=base / max(full, 1e-12))


if __name__ == "__main__":
    main()
