"""Paper Fig. 10: division-granularity sweep — naive fixed division count
vs CoDec's adaptive division + scheduling."""

from __future__ import annotations

from benchmarks.common import emit, paper_cost_model
from repro.core import tree as tree_mod
from repro.core.scheduler import (TaskSpec, divide_and_schedule, lpt,
                                  naive_divide)

PAGE = 64
LANES = 8


def main() -> None:
    cm = paper_cost_model(PAGE)
    workloads = {
        "docqa_120k": tree_mod.two_level(32, 120_000 // PAGE * PAGE,
                                         2048, PAGE),
        "kary_d4": tree_mod.full_kary(4, 2, 16384, PAGE),
    }
    for wname, f in workloads.items():
        tasks = [TaskSpec(n.id, len(n.requests), n.length)
                 for n in f.real_nodes()]
        best_naive = None
        for k in (1, 2, 4, 8, 16, 32, 64):
            subs = naive_divide(tasks, k, cm, PAGE)
            _, lane_cost = lpt(subs, LANES)
            mk = max(lane_cost)
            emit("fig10", f"{wname}_naive_k{k}", makespan_ms=mk * 1e3,
                 subtasks=len(subs))
            best_naive = mk if best_naive is None else min(best_naive, mk)
        sched = divide_and_schedule(tasks, cm, LANES, PAGE)
        emit("fig10", f"{wname}_adaptive",
             makespan_ms=sched.makespan * 1e3,
             subtasks=len(sched.subtasks),
             vs_best_naive=best_naive / max(sched.makespan, 1e-12),
             vs_no_division=(lambda: (lambda s1: max(s1))(
                 lpt(naive_divide(tasks, 1, cm, PAGE), LANES)[1])
                 / max(sched.makespan, 1e-12))())


if __name__ == "__main__":
    main()
