"""Paper Fig. 6: global-memory access, CoDec vs FlashDecoding.

Two independent counts that must agree:
* analytic (forest totals: every node read once vs once-per-request);
* plan-level (sum of KV page bytes over the compiled step arrays).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, paper_cost_model
from repro.core import plan as plan_mod, tree as tree_mod

PAGE = 64


def plan_io_bytes(p, n_kv: int, d: int, bytes_per: int = 2) -> int:
    """KV bytes the kernel streams: valid steps x page bytes."""
    page_bytes = 2 * p.page_size * n_kv * d * bytes_per
    return int(p.step_valid.sum()) * page_bytes


def main() -> None:
    cm = paper_cost_model(PAGE)
    workloads = {
        "2level_120k_b32": tree_mod.two_level(32, 120_000 // PAGE * PAGE,
                                              2048, PAGE),
        "2level_120k_b128": tree_mod.two_level(128, 120_000 // PAGE * PAGE,
                                               2048, PAGE),
        "kary_d4": tree_mod.full_kary(4, 2, 8192, PAGE),
        "degenerate_d8": tree_mod.degenerate(8, 8192, PAGE),
        "ratio99": tree_mod.shared_ratio(32, 120_000, 0.99, PAGE),
    }
    for name, f in workloads.items():
        plan_mod.assign_dense_pages(f)
        pc = plan_mod.build_plan(f, cm, 8, 256, 8192)
        pf = plan_mod.flash_plan(f, cm, 8, 256, 8192)
        io_c = plan_io_bytes(pc, cm.h_kv, cm.d)
        io_f = plan_io_bytes(pf, cm.h_kv, cm.d)
        ana_c = f.codec_io_bytes(cm.h_kv, cm.d)
        ana_f = f.flash_io_bytes(cm.h_kv, cm.d)
        # plan-level counts include partial-page padding; must be within
        # one page per task of the analytic count
        assert io_c >= ana_c and io_c - ana_c <= pc.num_tasks * 2 * PAGE * cm.h_kv * cm.d * 2
        emit("fig6", name,
             io_codec_mb=io_c / 1e6, io_flash_mb=io_f / 1e6,
             reduction=io_f / max(io_c, 1),
             analytic_reduction=ana_f / max(ana_c, 1),
             mean_sharing=f.mean_sharing_degree())


if __name__ == "__main__":
    main()
