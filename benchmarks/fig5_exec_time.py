"""Paper Fig. 5: CoDec vs FlashDecoding attention execution time.

Sweeps the paper's five workload axes (sequence length, batch size, tree
depth, shared-prefix ratio, tree shape) and reports the cost-model
makespans of the two plans on identical forests, plus the exact IO.
The modeled speedup reproduces the paper's trends: bigger share -> bigger
win; irregular (degenerate) trees win more than balanced ones.
"""

from __future__ import annotations

from benchmarks.common import codec_vs_flash, emit, paper_cost_model
from repro.core import tree as tree_mod

PAGE = 64


def main() -> None:
    cm = paper_cost_model(PAGE)

    # varying non-shared sequence length (binary depth-2 tree, 120k root)
    for unique in (512, 1024, 2048, 4096, 8192):
        f = tree_mod.two_level(32, 120_000 // PAGE * PAGE, unique, PAGE)
        r = codec_vs_flash(f, cm)
        emit("fig5_seqlen", f"unique{unique}", **r)

    # varying batch size
    for bs in (4, 8, 16, 32, 64, 128):
        f = tree_mod.two_level(bs, 120_000 // PAGE * PAGE, 2048, PAGE)
        r = codec_vs_flash(f, cm)
        emit("fig5_batch", f"bs{bs}", **r)

    # varying tree depth (full binary)
    for depth in (2, 3, 4, 5, 6):
        f = tree_mod.full_kary(depth, 2, 8192, PAGE)
        r = codec_vs_flash(f, cm)
        emit("fig5_depth", f"d{depth}", **r)

    # varying shared ratio at fixed 120k context
    for ratio in (0.5, 0.8, 0.9, 0.99):
        f = tree_mod.shared_ratio(32, 120_000, ratio, PAGE)
        r = codec_vs_flash(f, cm)
        emit("fig5_ratio", f"r{ratio}", **r)

    # varying tree shape (same per-node workload)
    shapes = {"2T": tree_mod.full_kary(4, 2, 8192, PAGE),
              "3T": tree_mod.full_kary(3, 3, 8192, PAGE),
              "4T": tree_mod.full_kary(3, 4, 8192, PAGE),
              "5T": tree_mod.full_kary(3, 5, 8192, PAGE),
              "DT": tree_mod.degenerate(8, 8192, PAGE)}
    for name, f in shapes.items():
        r = codec_vs_flash(f, cm)
        emit("fig5_shape", name, **r)


if __name__ == "__main__":
    main()
