"""Paper Table 2: PAC execution-time profile over (n_q, n).

The paper profiles thread-block time on the target GPU; we emit the
TPU-v5e analytic estimator C_est(n_q, n) over the same grid (plus the
memory/compute-bound classification that motivates profile-based
estimation) and, optionally, an interpret-mode measured table.
"""

from __future__ import annotations

from benchmarks.common import emit, paper_cost_model

N_QS = (1, 2, 5, 10, 20, 50, 100)
NS = (512, 1024, 2048, 4096, 8192, 16384)


def main() -> None:
    cm = paper_cost_model()
    for n in NS:
        for nq in N_QS:
            est = cm(nq, n)
            emit("table2", f"nq{nq}_n{n}",
                 us_per_call=est * 1e6,
                 est_ms=est * 1e3,
                 bound=cm.bound(nq, n),
                 flops=cm.flops(nq, n),
                 hbm_bytes=cm.hbm_bytes(nq, n))


if __name__ == "__main__":
    main()
