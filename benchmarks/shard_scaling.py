"""Shard-scaling benchmark: decode TPOT + makespan vs device count.

Sweeps fake-device counts (1 / 2 / 4 by default) and, for each, runs
the SPMD sharded decode engine (``distributed/``) on a ``Dx1`` mesh
over the same doc-QA workload in a **subprocess** (the device count is
fixed at jax backend init, so every count needs its own process).

Fake host devices SERIALIZE on the local CPU cores and pay a
per-step multi-device dispatch cost a real mesh does not, so raw
wall-clock at ``D > 1`` measures emulation overhead, not scaling.
Each child therefore reports two latencies:

* ``wall_tpot_ms`` — raw warm-pass wall per decode step (pass 0 runs
  calibrated/blocking to collect per-step timings, fits the cost
  model, then pass 1 is timed with async dispatch).  A regression
  canary only: it grows ~linearly in D by construction of the
  emulation.
* ``model_step_us`` — the cost model's prediction of the per-step
  attention + merge time on a REAL mesh (heaviest shard's HBM/grid
  terms + sparse-merge wire/launch,
  ``DecodeEngine.predicted_step_seconds``), evaluated under the
  DATASHEET hardware spec so the number is comparable across child
  processes (online fits reject decode-steady features as
  unidentifiable — see ``CostModel.fit``).

The parent projects a real-mesh TPOT from the two: the dense
(FFN/unembed/dispatch) base cost is device-count-independent — the
compiled per-device program is identical across D — so

    ``tpot_ms(D) = wall_tpot_ms(1dev) + model_step_us(D)/1e3
                                      - model_step_us(1dev)/1e3``

i.e. the measured single-device step wall shifted by the model's
per-shard attention/merge delta.  ``tpot_vs_1dev`` (the CI gate) is
computed from this projection; ``wall_tpot_vs_1dev`` keeps the raw
ratio visible.  With replication promoting the hot shared prefix the
smoke-scale delta is ~zero (no merge rows, same local reads); on the
``longdoc`` preset sequence-splitting the prefix makes the projection
strictly BEAT one device (per-shard HBM ~1/D, small sparse merge).

``python -m benchmarks.shard_scaling [--preset smoke] [--devices 1,2,4]``
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

OUT = os.environ.get("BENCH_SHARD_OUT", "BENCH_shard.json")

CHILD = textwrap.dedent("""\
    import json, os, sys, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(devices)d")
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    from repro.distributed import decode_mesh

    DEV = %(devices)d
    DOC_LEN = %(doc_len)d
    REQUESTS = %(requests)d
    MAX_NEW = %(max_new)d
    PAGE = 16

    cfg = smoke_config("%(arch)s")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 10 + DOC_LEN))
    eng = DecodeEngine(cfg, params, page_size=PAGE, num_pages=%(pages)d,
                       backend="%(backend)s", max_q=max(REQUESTS, 8),
                       temperature=0.0, fused=True,
                       mesh=decode_mesh(DEV, 1),
                       seq_split_pages=2 if DEV > 1 else 0,
                       replicate=True, calibrate=True)
    hw0 = eng.cost_model.hw      # datasheet spec: cross-child comparable
    passes = []
    for pno in range(2):
        prompts = [doc + [200 + 16 * pno + 4 * i + j for j in range(4)]
                   for i in range(REQUESTS)]
        for p in prompts:
            eng.add_request(p, max_new=MAX_NEW)
        eng.step()                       # absorb prefill + first compile
        steps0 = eng.stats["steps"]
        t0 = time.perf_counter()
        while eng.has_work():
            eng.step()
        eng.flush_tokens()
        jax.block_until_ready(eng.pool.k)
        wall = time.perf_counter() - t0
        steps = max(eng.stats["steps"] - steps0, 1)
        passes.append(dict(wall_s=wall, steps=steps,
                           tpot_ms=wall / steps * 1e3))
        if pno == 0:
            # pass 0 ran calibrated (each dispatch blocked -> true step
            # seconds); install the fit, then time pass 1 with async
            # dispatch -- the serving configuration being benchmarked
            eng.recalibrate(min_samples=4)
            eng.calibrate = False
    sp = eng._sharded_plans.get(0)
    ps = sp.stats()
    hw = eng.cost_model.hw
    out = dict(devices=DEV, wall_tpot_ms=passes[1]["tpot_ms"],
               steps=passes[1]["steps"],
               model_step_us=eng.predicted_step_seconds(hw=hw0) * 1e6,
               compile_count=eng.fused_cache_size,
               bucket_signatures=len(eng.bucket_signatures),
               replans=eng.stats["replans"],
               makespan_us=sp.makespan * 1e6,
               merge_cost_us=sp.merge_cost * 1e6,
               local_makespan_us=(sp.makespan - sp.merge_cost) * 1e6,
               seq_splits=sp.seq_splits,
               replicated_nodes=ps["replicated_nodes"],
               merge_rows=ps["merge_row_count"],
               replica_promotions=eng.stats["replica_promotions"],
               calibrated=eng.cost_model.calibrated,
               calibrations=eng.stats["calibrations"],
               fitted_hbm_gbps=hw.hbm_bw / 1e9,
               fitted_ici_gbps=hw.ici_bw / 1e9,
               shard_occupancy=eng.pool.shard_occupancy())
    print("RESULT " + json.dumps(out))
""")


def run_child(devices: int, arch: str, backend: str, doc_len: int,
              requests: int, max_new: int, pages: int = 1024) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)          # the child pins its own
    code = CHILD % dict(devices=devices, arch=arch, backend=backend,
                        doc_len=doc_len, requests=requests,
                        max_new=max_new, pages=pages)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"child ({devices} devices) failed:\n"
                       f"{r.stdout[-1500:]}\n{r.stderr[-3000:]}")


def main() -> None:
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "full", "longdoc"],
                    default="smoke")
    ap.add_argument("--devices", default="1,2,4")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--backend", default="codec-xla")
    args, _ = ap.parse_known_args()

    # longdoc: one long shared document per request batch — the regime
    # where sequence-splitting the prefix across shards (parallel page
    # reads) beats a single device outright, replication stays off
    # (CostModel.replicate_gain goes negative), and the sparse merge
    # carries the whole batch
    presets = {"smoke": (96, 4, 8, 1024),
               "full": (256, 8, 16, 1024),
               "longdoc": (2048, 4, 16, 2048)}
    doc_len, requests, max_new, pages = presets[args.preset]
    counts = [int(x) for x in args.devices.split(",") if x]
    result = {"arch": args.arch, "backend": args.backend,
              "preset": args.preset,
              "config": dict(doc_len=doc_len, requests=requests,
                             max_new=max_new),
              "tpot_note": ("tpot_ms projects real-mesh TPOT: measured "
                            "1-device step wall + the calibrated model's "
                            "per-shard attention/merge delta (fake host "
                            "devices serialize, so raw wall_tpot_ms at "
                            "D>1 measures emulation overhead only)"),
              "sweep": []}
    base_wall = base_model = None
    for n in counts:
        row = run_child(n, args.arch, args.backend, doc_len, requests,
                        max_new, pages)
        if base_wall is None:
            base_wall = row["wall_tpot_ms"]
            base_model = row["model_step_us"]
        row["tpot_ms"] = (base_wall
                          + (row["model_step_us"] - base_model) / 1e3)
        row["tpot_vs_1dev"] = row["tpot_ms"] / max(base_wall, 1e-9)
        row["wall_tpot_vs_1dev"] = row["wall_tpot_ms"] / max(base_wall,
                                                             1e-9)
        result["sweep"].append(row)
        emit("shard_scaling", f"{n}dev",
             us_per_call=row["tpot_ms"] * 1e3,
             tpot_ms=row["tpot_ms"],
             wall_tpot_ms=row["wall_tpot_ms"],
             model_step_us=row["model_step_us"],
             makespan_us=row["makespan_us"],
             merge_cost_us=row["merge_cost_us"],
             seq_splits=row["seq_splits"],
             compiles=row["compile_count"])
    with open(OUT, "w") as f:
        json.dump(result, f, indent=1)
    span = ", ".join(f"{r['devices']}dev {r['tpot_ms']:.3f}ms "
                     f"(x{r['tpot_vs_1dev']:.2f}, model "
                     f"{r['model_step_us']:.1f}us, merge "
                     f"{r['merge_cost_us']:.2f}us)"
                     for r in result["sweep"])
    print(f"# wrote {OUT}: projected TPOT {span}")


if __name__ == "__main__":
    main()
