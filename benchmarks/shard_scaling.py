"""Shard-scaling benchmark: decode TPOT + makespan vs device count.

Sweeps fake-device counts (1 / 2 / 4 by default) and, for each, runs
the SPMD sharded decode engine (``distributed/``) on a ``Dx1`` mesh
over the same doc-QA workload in a **subprocess** (the device count is
fixed at jax backend init, so every count needs its own process).
Each child reports warm-pass decode TPOT, the sharded plan's measured
makespan estimate, and the ICI-aware *predicted* makespan (slowest
shard + ``CostModel.merge_cost`` — the term the scheduler charges for
cross-device POR merges); the parent collects everything into
``BENCH_shard.json`` next to ``BENCH_decode.json``.

Wall-clock on CPU fake devices measures dispatch/collective overhead,
not ICI: read TPOT as a regression canary and the makespan columns as
the model-level scaling story (paper §5 extended across a mesh).

``python -m benchmarks.shard_scaling [--preset smoke] [--devices 1,2,4]``
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

OUT = os.environ.get("BENCH_SHARD_OUT", "BENCH_shard.json")

CHILD = textwrap.dedent("""\
    import json, os, sys, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(devices)d")
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine
    from repro.distributed import decode_mesh

    DEV = %(devices)d
    DOC_LEN = %(doc_len)d
    REQUESTS = %(requests)d
    MAX_NEW = %(max_new)d
    PAGE = 16

    cfg = smoke_config("%(arch)s")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    doc = list(range(10, 10 + DOC_LEN))
    eng = DecodeEngine(cfg, params, page_size=PAGE, num_pages=1024,
                       backend="%(backend)s", max_q=max(REQUESTS, 8),
                       temperature=0.0, fused=True,
                       mesh=decode_mesh(DEV, 1),
                       seq_split_pages=2 if DEV > 1 else 0)
    passes = []
    for pno in range(2):
        prompts = [doc + [200 + 16 * pno + 4 * i + j for j in range(4)]
                   for i in range(REQUESTS)]
        for p in prompts:
            eng.add_request(p, max_new=MAX_NEW)
        eng.step()                       # absorb prefill + first compile
        steps0 = eng.stats["steps"]
        t0 = time.perf_counter()
        while eng.has_work():
            eng.step()
        eng.flush_tokens()
        jax.block_until_ready(eng.pool.k)
        wall = time.perf_counter() - t0
        steps = max(eng.stats["steps"] - steps0, 1)
        passes.append(dict(wall_s=wall, steps=steps,
                           tpot_ms=wall / steps * 1e3))
    sp = eng._sharded_plans.get(0)
    out = dict(devices=DEV, tpot_ms=passes[1]["tpot_ms"],
               steps=passes[1]["steps"],
               compile_count=eng.fused_cache_size,
               bucket_signatures=len(eng.bucket_signatures),
               replans=eng.stats["replans"],
               makespan_us=sp.makespan * 1e6,
               merge_cost_us=sp.merge_cost * 1e6,
               local_makespan_us=(sp.makespan - sp.merge_cost) * 1e6,
               seq_splits=sp.seq_splits,
               shard_occupancy=eng.pool.shard_occupancy())
    print("RESULT " + json.dumps(out))
""")


def run_child(devices: int, arch: str, backend: str, doc_len: int,
              requests: int, max_new: int) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)          # the child pins its own
    code = CHILD % dict(devices=devices, arch=arch, backend=backend,
                        doc_len=doc_len, requests=requests,
                        max_new=max_new)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"child ({devices} devices) failed:\n"
                       f"{r.stdout[-1500:]}\n{r.stderr[-3000:]}")


def main() -> None:
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--devices", default="1,2,4")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--backend", default="codec-xla")
    args, _ = ap.parse_known_args()

    smoke = args.preset == "smoke"
    doc_len, requests, max_new = (96, 4, 8) if smoke else (256, 8, 16)
    counts = [int(x) for x in args.devices.split(",") if x]
    result = {"arch": args.arch, "backend": args.backend,
              "preset": args.preset,
              "config": dict(doc_len=doc_len, requests=requests,
                             max_new=max_new),
              "sweep": []}
    base_tpot = None
    for n in counts:
        row = run_child(n, args.arch, args.backend, doc_len, requests,
                        max_new)
        if base_tpot is None:
            base_tpot = row["tpot_ms"]
        row["tpot_vs_1dev"] = row["tpot_ms"] / max(base_tpot, 1e-9)
        result["sweep"].append(row)
        emit("shard_scaling", f"{n}dev",
             us_per_call=row["tpot_ms"] * 1e3,
             tpot_ms=row["tpot_ms"],
             makespan_us=row["makespan_us"],
             merge_cost_us=row["merge_cost_us"],
             seq_splits=row["seq_splits"],
             compiles=row["compile_count"])
    with open(OUT, "w") as f:
        json.dump(result, f, indent=1)
    span = ", ".join(f"{r['devices']}dev {r['makespan_us']:.1f}us"
                     f" (merge {r['merge_cost_us']:.2f}us)"
                     for r in result["sweep"])
    print(f"# wrote {OUT}: predicted makespan {span}")


if __name__ == "__main__":
    main()
