"""Core NN layers, functional style (params = nested dicts of jnp arrays).

Everything is written with named einsums over explicit head dimensions so
pjit sharding propagates cleanly; full-sequence attention is q-chunked
(scan) to keep activation memory O(T * chunk) for 32k prefill.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

Params = Dict[str, Any]
MASK_VALUE = -1e30

# ---------------------------------------------------------------------- #
# activation sharding hints (GSPMD constraints at layer boundaries)
# ---------------------------------------------------------------------- #
# GSPMD propagation alone re-replicates activations around gathers/scans
# (measured: 43 GB/step of QKV all-gathers on the 1T MoE cell).  The
# launcher registers the mesh here; `hint` then pins batch -> (pod, data)
# and optionally the trailing feature dim -> model, exactly like
# MaxText's activation-sharding annotations.  A no-op when no mesh is
# registered (tests, single-device engine).
_ACT_MESH = None


def set_activation_mesh(mesh) -> None:
    global _ACT_MESH
    _ACT_MESH = mesh


def hint(x: jnp.ndarray, model_last: bool = False,
         batch_dim: int = 0) -> jnp.ndarray:
    mesh = _ACT_MESH
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = [None] * x.ndim
    if dp and x.shape[batch_dim] % int(np.prod(
            [mesh.shape[a] for a in dp])) == 0:
        spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    if (model_last and "model" in mesh.axis_names
            and x.shape[-1] % mesh.shape["model"] == 0):
        spec[-1] = "model"
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# --------------------------------------------------------------------- #
# initialisers / primitives
# --------------------------------------------------------------------- #
def _init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    p = {"w": _init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, cfg: ModelConfig, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) / jnp.sqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        rms = jnp.sqrt(jnp.mean(xf * xf, -1, keepdims=True) + cfg.norm_eps)
        y = xf / rms * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, hd); positions: (B, T)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _softcap(s: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(s / cap) * cap if cap > 0 else s


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * hd, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], d, hkv * hd, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], d, hkv * hd, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
    }


def attn_project(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: Optional[jnp.ndarray],
                 use_rope: bool = True,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, T, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, T, hq, hd)
    k = dense(p["wk"], x).reshape(B, T, hkv, hd)
    v = dense(p["wv"], x).reshape(B, T, hkv, hd)
    if use_rope and cfg.pos_embedding == "rope" and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool, window: int = 0, softcap: float = 0.0,
        q_positions: Optional[jnp.ndarray] = None,
        kv_positions: Optional[jnp.ndarray] = None,
        kv_valid: Optional[jnp.ndarray] = None,
        q_chunk: int = 512, kv_layout: str = "blhd") -> jnp.ndarray:
    """Full attention, q-chunked. q: (B,Tq,Hq,hd); k/v: (B,Tk,Hkv,hd)
    ("blhd", projection layout) or (B,Hkv,Tk,hd) ("bhld", the head-major
    decode-cache layout — contraction-ready, no cache-sized transpose)."""
    B, Tq, Hq, hd = q.shape
    if kv_layout == "bhld":
        Tk, Hkv = k.shape[2], k.shape[1]
    else:
        Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = 1.0 / np.sqrt(hd)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Tq)[None], (B, Tq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Tk)[None], (B, Tk))

    qf = q.reshape(B, Tq, Hkv, g, hd)
    q_chunk = min(q_chunk, Tq)
    nchunks = -(-Tq // q_chunk)
    pad = nchunks * q_chunk - Tq
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    qf = qf.reshape(B, nchunks, q_chunk, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_positions.reshape(B, nchunks, q_chunk).transpose(1, 0, 2)

    k_sub = "bhkd" if kv_layout == "bhld" else "bkhd"

    def chunk_attn(args):
        qc, qpc = args                                  # (B,C,Hkv,g,hd), (B,C)
        # keep K/V in their storage dtype; accumulate in f32 on the MXU
        # (an explicit astype(f32) would materialise a 2x-sized copy of
        # the whole KV cache — decode-roofline poison)
        s = jnp.einsum(f"bchgd,{k_sub}->bhgck", qc, k,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        mask = jnp.ones((B, qpc.shape[1], Tk), bool)
        if causal:
            mask &= kv_positions[:, None, :] <= qpc[:, :, None]
        if window > 0:
            mask &= kv_positions[:, None, :] > qpc[:, :, None] - window
        if kv_valid is not None:
            mask &= kv_valid[:, None, :]
        mask &= qpc[:, :, None] >= 0
        # s: (B, Hkv, g, C, Tk); mask: (B, C, Tk)
        s = jnp.where(mask[:, None, None, :, :], s, MASK_VALUE)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(f"bhgck,{k_sub}->bchgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o

    out = jax.lax.map(chunk_attn, (qf, qp))            # (n,B,C,Hkv,g,hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nchunks * q_chunk, Hq, hd)
    if pad:
        out = out[:, :Tq]
    return out.astype(q.dtype)


def attn_full(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, causal: bool = True,
              window: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence self-attention. Returns (y, k, v) for caching."""
    q, k, v = attn_project(p, cfg, x, positions)
    o = mha(q, k, v, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap,
            q_positions=positions, kv_positions=positions)
    B, T = x.shape[:2]
    y = dense(p["wo"], o.reshape(B, T, cfg.num_heads * cfg.head_dim))
    return y, k, v


def attn_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                cache_len: jnp.ndarray, *, window: int = 0,
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a dense cache.

    x: (B, 1, d); caches: (B, L, Hkv, hd); cache_len: (B,) tokens already
    present (the new token's KV is appended by the caller *before* calling,
    at index cache_len, so attention covers cache_len+1 positions).
    Returns (y, k_new, v_new).
    """
    B = x.shape[0]
    positions = cache_len[:, None]                       # (B, 1)
    q, k_new, v_new = attn_project(p, cfg, x, positions)
    L = k_cache.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    kv_valid = kv_pos <= cache_len[:, None]              # includes new token
    o = mha(q, k_cache, v_cache, causal=True, window=window,
            softcap=cfg.attn_logit_softcap, q_positions=positions,
            kv_positions=kv_pos, kv_valid=kv_valid, q_chunk=1)
    y = dense(p["wo"], o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
    return y, k_new, v_new


def cross_attn_full(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    enc_k: jnp.ndarray, enc_v: jnp.ndarray,
                    kv_layout: str = "blhd") -> jnp.ndarray:
    """Cross attention (no rope, bidirectional over encoder output)."""
    B, T, _ = x.shape
    hq, hd = cfg.num_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, T, hq, hd)
    o = mha(q, enc_k, enc_v, causal=False,
            softcap=cfg.attn_logit_softcap, kv_layout=kv_layout)
    return dense(p["wo"], o.reshape(B, T, hq * hd))


def cross_kv(p: Params, cfg: ModelConfig, enc_out: jnp.ndarray):
    B, S, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = dense(p["wk"], enc_out).reshape(B, S, hkv, hd)
    v = dense(p["wv"], enc_out).reshape(B, S, hkv, hd)
    return k, v


# --------------------------------------------------------------------- #
# FFN: dense MLP and MoE
# --------------------------------------------------------------------- #
def mlp_init(key, cfg: ModelConfig, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    gate = 2 if cfg.mlp_act in ("silu", "geglu") else 1
    return {"wi": dense_init(k1, d, gate * ff, dtype),
            "wo": dense_init(k2, ff, d, dtype)}


def _act(h: jnp.ndarray, kind: str, ff: int) -> jnp.ndarray:
    if kind == "silu":
        g, u = h[..., :ff], h[..., ff:]
        return jax.nn.silu(g) * u
    if kind == "geglu":
        g, u = h[..., :ff], h[..., ff:]
        return jax.nn.gelu(g) * u
    return jax.nn.gelu(h)


def apply_mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = dense(p["wi"], x)
    return dense(p["wo"], _act(h, cfg.mlp_act, cfg.d_ff))


def apply_ffn_block(p: Params, cfg: ModelConfig, ffn: str, x: jnp.ndarray,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Post-mixer FFN half of a sub-layer: ln2 + (mlp|moe) + residual.

    Shared by the full-sequence, dense-decode, and fused paged-decode
    paths so all three stay op-identical.  Returns ``(x, moe_aux)``
    (``aux`` is zero for non-MoE ffn kinds).
    """
    aux = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, aux
    h2 = apply_norm(p["ln2"], x, cfg)
    if ffn == "moe":
        y2, aux = apply_moe(p["ffn"], cfg, h2)
    else:
        y2 = apply_mlp(p["ffn"], cfg, h2)
    return x + y2, aux


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    gate = 2 if cfg.mlp_act in ("silu", "geglu") else 1
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": _init(k1, (d, E), jnp.float32),  # router kept f32
        "wi": _init(k2, (E, d, gate * ff), dtype),
        "wo": _init(k3, (E, ff, d), dtype),
    }


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k token-choice MoE with capacity, sort-based dispatch.

    x: (B, T, d). Returns (y, aux_loss). Experts are sharded over the
    `model` mesh axis via the leading E dim of wi/wo (EP); the
    scatter/gather dispatch becomes collectives under pjit.

    The dispatch avoids the GShard (n, E, cap) one-hot tensors — at a
    1M-token global batch those are O(1e13) elements.  Instead the (n*k)
    token-choice pairs are stable-sorted by expert id (preserving the
    token-order drop priority of the one-hot formulation), queue
    positions computed with a segment count, and tokens scattered into
    the (E, cap, d) expert buffers; everything stays O(n*k) + O(E*cap).
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    ff = cfg.d_ff
    n_total = B * T
    G = max(1, cfg.moe_groups)
    assert n_total % G == 0, (n_total, G)
    n = n_total // G                                        # tokens/group
    nk = n * k
    f32 = jnp.float32
    # group axis = the DP sharding unit: routing, queue positions and the
    # scatter/gather all use group-local indices, so under pjit the token
    # tensor never leaves its shard; only the expert einsum communicates.
    # (Explicit G-batched ops, not vmap: GSPMD reshards vmapped
    # gather/scatter pathologically.)
    xg = x.reshape(G, n, d)

    # capacity per group: capacity_factor <= 0 selects the no-drop bound
    # (cap = n*k): exact but memory-heavier; tests / small-batch decode.
    cap = (nk if cfg.capacity_factor <= 0
           else max(1, int(cfg.capacity_factor * nk / E)))

    logits = jnp.einsum("gnd,de->gne", xg.astype(f32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, k)                # (G, n, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # queue position of each (token, choice) within its (group, expert)
    # queue: stable sort by expert id keeps ties in flat (token-major)
    # order, matching the cumsum-of-one-hot priority rule.
    eid = idx.reshape(G, nk)
    order = jnp.argsort(eid, axis=1, stable=True)
    sorted_eid = jnp.take_along_axis(eid, order, axis=1)
    eid_off = (eid + jnp.arange(G, dtype=jnp.int32)[:, None] * E).reshape(-1)
    counts = jax.ops.segment_sum(jnp.ones((G * nk,), jnp.int32), eid_off,
                                 num_segments=G * E).reshape(G, E)
    starts = jnp.cumsum(counts, axis=1) - counts            # (G, E)
    pos_sorted = (jnp.arange(nk, dtype=jnp.int32)[None]
                  - jnp.take_along_axis(starts, sorted_eid, axis=1))
    pos = jnp.zeros((G, nk), jnp.int32).at[
        jnp.arange(G)[:, None], order].set(pos_sorted)
    keep = pos < cap                                        # (G, nk)
    dst_c = jnp.minimum(pos, cap - 1)

    # load-balancing aux loss (Switch): E * mean_g sum_e f_e * p_e
    top1_off = (idx[..., 0] + jnp.arange(G)[:, None] * E).reshape(-1)
    density = (jax.ops.segment_sum(jnp.ones((G * n,), f32), top1_off,
                                   num_segments=G * E).reshape(G, E) / n)
    aux = E * jnp.mean(jnp.sum(density * probs.mean(1), axis=-1))

    # dispatch buffers stay in the activation dtype: every (g, e, c) slot
    # receives exactly one token (queue positions are unique), so the
    # scatter is a permutation — no low-precision accumulation; at bf16
    # this halves the dispatch-buffer traffic vs an f32 dispatch.
    cdt = x.dtype
    tok = jnp.arange(nk, dtype=jnp.int32) // k              # group-local
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None]          # (G, 1)
    vals = xg[gidx, tok[None]] * keep[..., None].astype(cdt)
    xin = jnp.zeros((G, E, cap, d), cdt).at[gidx, eid, dst_c].add(vals)

    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"],
                   preferred_element_type=f32)
    h = _act(h, cfg.mlp_act, ff).astype(cdt)
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"],
                     preferred_element_type=f32)

    gate_flat = gate_vals.reshape(G, nk) * keep.astype(f32)
    picked = out[gidx, eid, dst_c] * gate_flat[..., None]   # (G, nk, d)
    y = jnp.zeros((G, n, d), f32).at[gidx, tok[None]].add(picked)
    return y.reshape(B, T, d).astype(x.dtype), aux
