"""Composable decoder-LM stack covering all assigned architectures.

The layer stack is expressed as a repeating *period* of heterogeneous
sub-layers (``cfg.layer_pattern``); the forward pass `lax.scan`s over
periods with stacked parameters, keeping the lowered HLO O(period) —
essential when compiling 48-64 layer models for 512 devices.  Remainder
layers (num_layers % period) are unrolled.

Supports: dense/GQA/MQA attention (+QKV bias, sliding window, softcap),
SwiGLU/GeGLU/GELU FFN, top-k MoE, Mamba-2 mixers, hybrid patterns,
encoder-decoder with cross attention (audio frontend stub), and VLM
prefix embeddings (vision frontend stub).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LayerKind, ModelConfig
from . import layers as L
from . import mamba as M

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig, override=None):
    return override or jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _sublayer_init(key, cfg: ModelConfig, kind: LayerKind, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln": L.norm_init(cfg.d_model, cfg, dtype)}
    if kind.mixer in ("attn", "attn_local"):
        p["attn"] = L.attn_init(ks[0], cfg, dtype)
        if cfg.cross_attention:
            p["ln_x"] = L.norm_init(cfg.d_model, cfg, dtype)
            p["xattn"] = L.attn_init(ks[3], cfg, dtype, cross=True)
    elif kind.mixer == "mamba":
        p["mamba"] = M.mamba_init(ks[0], cfg, dtype)
    if kind.ffn != "none":
        p["ln2"] = L.norm_init(cfg.d_model, cfg, dtype)
        p["ffn"] = (L.moe_init(ks[1], cfg, dtype) if kind.ffn == "moe"
                    else L.mlp_init(ks[1], cfg, dtype))
    return p


def _period_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, cfg.period)
    return {f"sub{i}": _sublayer_init(ks[i], cfg, cfg.layer_pattern[i], dtype)
            for i in range(cfg.period)}


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = _dtype(cfg, dtype)
    k_embed, k_blocks, k_rem, k_head, k_enc = jax.random.split(key, 5)
    params: Params = {
        # 1/sqrt(d) embedding init keeps tied-head logits ~unit-scale at
        # init (with embed_scale the input embeddings are still ~N(0,1))
        "embed": L._init(k_embed, (cfg.vocab_size, cfg.d_model), dtype,
                         scale=cfg.d_model ** -0.5),
        "final_norm": L.norm_init(cfg.d_model, cfg, dtype),
    }
    if cfg.num_periods > 0:
        pk = jax.random.split(k_blocks, cfg.num_periods)
        stacked = [_period_init(pk[i], cfg, dtype)
                   for i in range(cfg.num_periods)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if cfg.remainder_layers:
        rk = jax.random.split(k_rem, cfg.remainder_layers)
        params["rem"] = [
            _sublayer_init(rk[i], cfg, cfg.layer_pattern[i], dtype)
            for i in range(cfg.remainder_layers)]
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)
    if cfg.encoder_layers:
        ek = jax.random.split(k_enc, cfg.encoder_layers + 1)
        params["encoder"] = {
            "layers": [_sublayer_init(ek[i], cfg, LayerKind("attn", "mlp"),
                                      dtype)
                       for i in range(cfg.encoder_layers)],
            "final_norm": L.norm_init(cfg.d_model, cfg, dtype),
        }
    return params


# --------------------------------------------------------------------- #
# sub-layer forward (full sequence)
# --------------------------------------------------------------------- #
def _sub_forward(p: Params, cfg: ModelConfig, kind: LayerKind,
                 x: jnp.ndarray, positions: jnp.ndarray,
                 enc_out: Optional[jnp.ndarray], aux: jnp.ndarray,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, Params]:
    """Returns (x, aux, cache_entries) for one sub-layer over a full seq."""
    cache: Params = {}
    h = L.apply_norm(p["ln"], x, cfg)
    if kind.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if kind.mixer == "attn_local" else 0
        y, k, v = L.attn_full(p["attn"], cfg, h, positions, causal=True,
                              window=window)
        cache["k"], cache["v"] = k, v
        x = x + y
        if cfg.cross_attention and enc_out is not None:
            hx = L.apply_norm(p["ln_x"], x, cfg)
            ek, ev = L.cross_kv(p["xattn"], cfg, enc_out)
            x = x + L.cross_attn_full(p["xattn"], cfg, hx, ek, ev)
            cache["xk"], cache["xv"] = ek, ev
    elif kind.mixer == "mamba":
        y, (conv_s, ssm_s) = M.mamba_forward(p["mamba"], cfg, h)
        cache["conv"], cache["ssm"] = conv_s, ssm_s
        x = x + y
    x, a = L.apply_ffn_block(p, cfg, kind.ffn, x)
    return x, aux + a, cache


def _encode(params: Params, cfg: ModelConfig,
            encoder_embeds: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional encoder over stubbed frontend embeddings."""
    x = encoder_embeds.astype(_dtype(cfg))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for lp in params["encoder"]["layers"]:
        h = L.apply_norm(lp["ln"], x, cfg)
        y, _, _ = L.attn_full(lp["attn"], cfg, h, positions, causal=False)
        x = x + y
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.apply_mlp(lp["ffn"], cfg, h2)
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg)


def _embed(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
           positions: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_embedding == "absolute":
        d = cfg.d_model
        half = d // 2
        freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
        ang = positions[..., None].astype(jnp.float32) * freq
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        x = x + pe.astype(x.dtype)
    return x


def _unembed(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = L.apply_norm(params["final_norm"], x, cfg)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x @ w).astype(jnp.float32)


# --------------------------------------------------------------------- #
# stacked-apply entry point (fused paged decode)
# --------------------------------------------------------------------- #
def mixer_offsets(cfg: ModelConfig) -> Tuple[List[int], List[int], int, int]:
    """Per-pattern-position attention/mamba ordinals within one period.

    Returns ``(attn_off, mamba_off, attn_per_period, mamba_per_period)``;
    the dense ordinal of pattern position ``i`` in period ``pi`` is
    ``pi * attn_per_period + attn_off[i]`` — the same period-major order
    ``serving.engine.flat_layers`` flattens to, i.e. the layer axis of
    the paged KV pool and of batched SSM state stacks.
    """
    attn_off, mamba_off = [], []
    na = nm = 0
    for kind in cfg.layer_pattern:
        attn_off.append(na)
        mamba_off.append(nm)
        if kind.mixer in ("attn", "attn_local"):
            na += 1
        elif kind.mixer == "mamba":
            nm += 1
    return attn_off, mamba_off, na, nm


def scan_layer_stack(cfg: ModelConfig, params: Params, body, carry):
    """Apply ``body`` to every sub-layer, scanning the period-stacked
    parameter pytree (``params["blocks"]``) and unrolling the remainder.

    ``body(carry, kind, p, attn_idx, mamba_idx) -> carry`` receives the
    sub-layer's parameters and its dense attention / mamba ordinals
    (traced scalars inside the scan, python ints for remainder layers) —
    what paged KV pools and batched recurrent-state stacks are indexed
    by.  Keeping the lowered HLO O(period) is what makes the fused
    decode step compile fast for deep models; ordering matches
    ``serving.engine.flat_layers`` exactly.
    """
    attn_off, mamba_off, A, M = mixer_offsets(cfg)
    pat = cfg.layer_pattern

    def period_body(c, xs):
        pp, pi = xs
        for i, kind in enumerate(pat):
            c = body(c, kind, pp[f"sub{i}"],
                     pi * A + attn_off[i], pi * M + mamba_off[i])
        return c, None

    if cfg.num_periods > 0:
        carry, _ = jax.lax.scan(
            period_body, carry,
            (params["blocks"], jnp.arange(cfg.num_periods)))
    for i in range(cfg.remainder_layers):
        carry = body(carry, pat[i], params["rem"][i],
                     cfg.num_periods * A + attn_off[i],
                     cfg.num_periods * M + mamba_off[i])
    return carry


# --------------------------------------------------------------------- #
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------- #
def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None,
            encoder_embeds: Optional[jnp.ndarray] = None,
            collect_cache: bool = False,
            remat: bool = False,
            last_only: bool = False,
            unroll: bool = False,
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    """Returns (logits, moe_aux, cache|None).

    ``prefix_embeds`` (VLM stub) are prepended; logits cover only the
    token positions.  ``encoder_embeds`` (audio stub) feed the encoder for
    cross attention.  ``remat`` checkpoints each period (activation
    rematerialisation for the training path); ``last_only`` unembeds only
    the final position (prefill: avoids the (B, T, V) logits tensor).
    """
    B, T = tokens.shape
    n_prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    total = T + n_prefix
    positions = jnp.broadcast_to(jnp.arange(total)[None], (B, total))
    x = _embed(params, cfg, tokens, positions[:, n_prefix:])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    enc_out = (_encode(params, cfg, encoder_embeds)
               if cfg.encoder_layers and encoder_embeds is not None else None)

    aux0 = jnp.zeros((), jnp.float32)

    def period_body(carry, period_params):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, aux, c = _sub_forward(period_params[f"sub{i}"], cfg, kind, x,
                                     positions, enc_out, aux)
            caches[f"sub{i}"] = c
        return (x, aux), (caches if collect_cache else 0)

    body = jax.checkpoint(period_body) if remat else period_body
    if cfg.num_periods > 0:
        if unroll:
            # python loop over periods: exact HLO cost accounting (XLA's
            # cost analysis counts while-loop bodies once; the dry-run
            # unrolls small-k models and extrapolates).
            carry, per_caches = (x, aux0), []
            for pi in range(cfg.num_periods):
                pp = jax.tree.map(lambda a: a[pi], params["blocks"])
                carry, c = body(carry, pp)
                per_caches.append(c)
            (x, aux) = carry
        else:
            (x, aux), per_caches = jax.lax.scan(body, (x, aux0),
                                                params["blocks"])
    else:
        aux, per_caches = aux0, None
    rem_caches = []
    for i in range(cfg.remainder_layers):
        kind = cfg.layer_pattern[i]
        x, aux, c = _sub_forward(params["rem"][i], cfg, kind, x, positions,
                                 enc_out, aux)
        rem_caches.append(c)

    x_out = x[:, -1:] if last_only else x[:, n_prefix:]
    logits = _unembed(params, cfg, x_out)
    cache = None
    if collect_cache:
        cache = {"blocks": per_caches, "rem": rem_caches}
    return logits, aux, cache


# --------------------------------------------------------------------- #
# dense decode cache
# --------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               enc_len: int = 0) -> Params:
    """Allocate a dense decode cache pytree (period-stacked)."""
    dtype = _dtype(cfg, dtype)
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    K = cfg.ssm_conv
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state

    def sub_cache(kind: LayerKind, lead=()):
        c: Params = {}
        if kind.mixer in ("attn", "attn_local"):
            win = cfg.sliding_window if kind.mixer == "attn_local" else 0
            ln = min(max_len, win + 1) if win else max_len
            # sliding-window layers only need a window-sized ring buffer,
            # but a dense cache keeps full length for simplicity of
            # position math; ring-buffering is the paged engine's job.
            ln = max_len
            # head-major (B, H, L, D): contraction-ready for the decode
            # QK^T/PV dots — avoids a cache-sized transpose every layer
            c["k"] = jnp.zeros(lead + (batch, hkv, ln, hd), dtype)
            c["v"] = jnp.zeros(lead + (batch, hkv, ln, hd), dtype)
            if cfg.cross_attention:
                c["xk"] = jnp.zeros(lead + (batch, hkv, enc_len, hd), dtype)
                c["xv"] = jnp.zeros(lead + (batch, hkv, enc_len, hd), dtype)
        elif kind.mixer == "mamba":
            c["conv"] = jnp.zeros(lead + (batch, K - 1, conv_dim), jnp.float32)
            c["ssm"] = jnp.zeros(
                lead + (batch, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32)
        return c

    cache: Params = {}
    if cfg.num_periods > 0:
        cache["blocks"] = {
            f"sub{i}": sub_cache(cfg.layer_pattern[i], (cfg.num_periods,))
            for i in range(cfg.period)}
    cache["rem"] = [sub_cache(cfg.layer_pattern[i])
                    for i in range(cfg.remainder_layers)]
    return cache


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            max_len: int, dtype=None,
            prefix_embeds=None, encoder_embeds=None,
            ) -> Tuple[jnp.ndarray, Params, jnp.ndarray]:
    """Process the full prompt; returns (last_logits, cache, cache_len)."""
    B, T = tokens.shape
    logits, _, col = forward(params, cfg, tokens, prefix_embeds,
                             encoder_embeds, collect_cache=True)
    cache = init_cache(cfg, B, max_len, dtype,
                       enc_len=(encoder_embeds.shape[1]
                                if encoder_embeds is not None else 0))

    n_prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    total = T + n_prefix

    def fill(dst, src):
        # dst (..., B, hkv, max_len, hd); src (..., B, total, hkv, hd)
        src = jnp.swapaxes(src, -3, -2)        # -> (..., B, hkv, total, hd)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), 0, axis=dst.ndim - 2)

    def place(dst_sub, src_sub):
        out = dict(dst_sub)
        for key in dst_sub:
            if key in ("k", "v"):
                out[key] = fill(dst_sub[key], src_sub[key])
            elif key in ("xk", "xv"):
                out[key] = jnp.swapaxes(src_sub[key], -3, -2).astype(
                    dst_sub[key].dtype)
            elif key in ("conv", "ssm"):
                out[key] = src_sub[key].astype(dst_sub[key].dtype)
        return out

    new_cache: Params = {"rem": []}
    if cfg.num_periods > 0:
        new_cache["blocks"] = {
            k: place(cache["blocks"][k], col["blocks"][k])
            for k in cache["blocks"]}
    for i in range(cfg.remainder_layers):
        new_cache["rem"].append(place(cache["rem"][i], col["rem"][i]))
    cache_len = jnp.full((B,), total, jnp.int32)
    return logits[:, -1], new_cache, cache_len


# --------------------------------------------------------------------- #
# decode step (dense cache)
# --------------------------------------------------------------------- #
def _sub_decode(p: Params, cfg: ModelConfig, kind: LayerKind,
                x: jnp.ndarray, cache: Params, cache_len: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Params]:
    B = x.shape[0]
    new_cache = dict(cache)
    h = L.apply_norm(p["ln"], x, cfg)
    if kind.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if kind.mixer == "attn_local" else 0
        # project first so we can append KV before attending
        q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                         cache_len[:, None])
        # cache layout (B, Hkv, L, hd): scatter the new token at
        # [b, h, cache_len[b]].  All-adjacent broadcast advanced indices
        # keep scatter dims in operand order — XLA emits an in-place
        # scatter instead of permuting the whole cache around it.
        H = cache["k"].shape[1]
        bidx = jnp.arange(B)[:, None]                  # (B, 1)
        hidx = jnp.arange(H)[None, :]                  # (1, H)
        pidx = cache_len[:, None]                      # (B, 1)
        k_cache = cache["k"].at[bidx, hidx, pidx].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, hidx, pidx].set(
            v_new[:, 0].astype(cache["v"].dtype))
        Lc = k_cache.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(Lc)[None], (B, Lc))
        kv_valid = kv_pos <= cache_len[:, None]
        o = L.mha(q, k_cache, v_cache, causal=True, window=window,
                  softcap=cfg.attn_logit_softcap,
                  q_positions=cache_len[:, None], kv_positions=kv_pos,
                  kv_valid=kv_valid, q_chunk=1, kv_layout="bhld")
        y = L.dense(p["attn"]["wo"],
                    o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
        new_cache["k"], new_cache["v"] = k_cache, v_cache
        x = x + y
        if cfg.cross_attention and "xk" in cache:
            hx = L.apply_norm(p["ln_x"], x, cfg)
            x = x + L.cross_attn_full(p["xattn"], cfg, hx,
                                      cache["xk"], cache["xv"],
                                      kv_layout="bhld")
    elif kind.mixer == "mamba":
        y, (conv_s, ssm_s) = M.mamba_decode(p["mamba"], cfg, h,
                                            cache["conv"], cache["ssm"])
        new_cache["conv"], new_cache["ssm"] = conv_s, ssm_s
        x = x + y
    x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)
    return x, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Params, cache_len: jnp.ndarray,
                unroll: bool = False,
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1); cache_len: (B,) tokens already
    cached (new token KV is written at index cache_len).
    Returns (logits (B, V), new_cache)."""
    B = tokens.shape[0]
    x = _embed(params, cfg, tokens, cache_len[:, None])

    def period_body(carry, inputs):
        x = carry
        period_params, period_cache = inputs
        new_caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc = _sub_decode(period_params[f"sub{i}"], cfg, kind, x,
                                period_cache[f"sub{i}"], cache_len)
            new_caches[f"sub{i}"] = nc
        return x, new_caches

    new_cache: Params = {"rem": []}
    if cfg.num_periods > 0:
        if unroll:
            outs = []
            for pi in range(cfg.num_periods):
                inp = jax.tree.map(lambda a: a[pi],
                                   (params["blocks"], cache["blocks"]))
                x, nc = period_body(x, inp)
                outs.append(nc)
            nb = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, nb = jax.lax.scan(period_body, x,
                                 (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = nb
    for i in range(cfg.remainder_layers):
        kind = cfg.layer_pattern[i]
        x, nc = _sub_decode(params["rem"][i], cfg, kind, x,
                            cache["rem"][i], cache_len)
        new_cache["rem"].append(nc)
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, new_cache
