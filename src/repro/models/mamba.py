"""Mamba-2 (SSD, state-space duality) mixer — arXiv:2405.21060.

Chunked SSD for train/prefill (intra-chunk quadratic + inter-chunk
associative scan over chunk states) and O(1) recurrent decode.  Group
count G=1 (B/C shared across heads).  A naive token-recurrence reference
is included for tests.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

Params = Dict[str, Any]


def mamba_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    d_in = cfg.d_inner
    S = cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = d_in + 2 * S
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * S + H   # z, xBC, dt
    return {
        "in_proj": {"w": (jax.random.normal(ks[0], (d, proj_out), jnp.float32)
                          / np.sqrt(d)).astype(dtype)},
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) / np.sqrt(cfg.ssm_conv)
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": {"w": (jax.random.normal(ks[2], (d_in, d), jnp.float32)
                           / np.sqrt(d_in)).astype(dtype)},
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * S]
    dt = zxbcdt[..., 2 * d_in + 2 * S:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d. xBC: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = init_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)            # (B, T+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i][None, None] for i in range(K))
    return jax.nn.silu((out + b[None, None]).astype(jnp.float32)).astype(xBC.dtype)


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                eps: float) -> jnp.ndarray:
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (yf / rms * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. x: (B,T,H,P); dt: (B,T,H); A: (H,) negative;
    Bm/Cm: (B,T,S).  Returns (y: (B,T,H,P), final_state: (B,H,P,S))."""
    Bsz, T, H, P = x.shape
    S = Bm.shape[-1]
    Q = min(chunk, T)
    nc = -(-T // Q)
    pad = nc * Q - T
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, S)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, S)

    dA = dtc * A[None, None, None, :]                   # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)

    # intra-chunk (diagonal) term: scores[i,j] = C_i.B_j e^{cum_i-cum_j} dt_j
    cb = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)          # (B,nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,i,j,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    w = jnp.where(causal, decay, 0.0) * dtc[:, :, None, :, :]       # (B,nc,i,j,H)
    y_diag = jnp.einsum("bnij,bnijh,bnjhp->bnihp", cb, w, xf)

    # chunk states: S_c = sum_j e^{cum_last - cum_j} dt_j B_j x_j^T
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc      # (B,nc,Q,H)
    states = jnp.einsum("bnjs,bnjh,bnjhp->bnhps", Bc, w_end, xf)  # (B,nc,H,P,S)
    gamma = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    # inter-chunk: associative scan  (g1,s1)*(g2,s2) = (g1g2, s1 g2 + s2)
    def op(a, b):
        g1, s1 = a
        g2, s2 = b
        return g1 * g2, s1 * g2[..., None, None] + s2

    g_in, s_in = gamma, states
    if init_state is not None:
        s0 = init_state.astype(jnp.float32)[:, None]    # (B,1,H,P,S)
        g0 = jnp.ones((Bsz, 1, H), jnp.float32)
        g_in = jnp.concatenate([g0, gamma], 1)
        s_in = jnp.concatenate([s0, states], 1)
    g_sc, s_sc = jax.lax.associative_scan(op, (g_in, s_in), axis=1)
    if init_state is not None:
        states_incl = s_sc[:, 1:]
        prev = s_sc[:, :-1]
    else:
        states_incl = s_sc
        prev = jnp.concatenate(
            [jnp.zeros_like(s_sc[:, :1]), s_sc[:, :-1]], 1)

    # off-diagonal term: y_i += C_i . prev_state * e^{cum_i}
    y_off = jnp.einsum("bnis,bnhps,bnih->bnihp", Cc, prev, jnp.exp(cum))
    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)
    if pad:
        y = y[:, :T]
    return y.astype(x.dtype), states_incl[:, -1]


def mamba_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence Mamba-2 block. x: (B,T,d).
    Returns (y, (conv_state, ssm_state)) for decode continuation."""
    B, T, _ = x.shape
    d_in, S, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]["w"]
    z, xBC_raw, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    x_ssm = xBC[..., :d_in].reshape(B, T, H, P)
    Bm = xBC[..., d_in:d_in + S]
    Cm = xBC[..., d_in + S:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + x_ssm.astype(jnp.float32).astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, T, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]["w"]
    K = cfg.ssm_conv
    conv_state = xBC_raw[:, -(K - 1):].astype(jnp.float32)
    if T < K - 1:
        conv_state = jnp.concatenate(
            [jnp.zeros((B, K - 1 - T, conv_state.shape[-1]), jnp.float32),
             conv_state], 1)
    return out, (conv_state, final_state)


def mamba_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state: jnp.ndarray, ssm_state: jnp.ndarray,
                 ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token recurrent step. x: (B,1,d); conv_state: (B,K-1,conv_dim);
    ssm_state: (B,H,P,S)."""
    B = x.shape[0]
    d_in, S, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]["w"]
    z, xBC_raw, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"],
                       init_state=conv_state)            # (B,1,conv_dim)
    new_conv = jnp.concatenate(
        [conv_state[:, 1:], xBC_raw.astype(jnp.float32)], 1)
    x_ssm = xBC[..., :d_in].reshape(B, H, P)
    Bm = xBC[:, 0, d_in:d_in + S]
    Cm = xBC[:, 0, d_in + S:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * A[None])                          # (B,H)
    xs = x_ssm.astype(jnp.float32)
    new_state = (ssm_state * dA[:, :, None, None]
                 + dt1[:, :, None, None] * xs[..., None]
                 * Bm.astype(jnp.float32)[:, None, None, :])
    y = jnp.einsum("bhps,bs->bhp", new_state, Cm.astype(jnp.float32))
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]["w"]
    return out, (new_conv, new_state)


def mamba_recurrent_ref(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                        ) -> jnp.ndarray:
    """Token-by-token reference (oracle for ssd_chunked)."""
    B, T, _ = x.shape
    d_in, S = cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv
    conv_dim = d_in + 2 * S
    conv_state = jnp.zeros((B, K - 1, conv_dim), jnp.float32)
    ssm_state = jnp.zeros((B, H, P, S), jnp.float32)
    outs = []
    for t in range(T):
        y, (conv_state, ssm_state) = mamba_decode(
            p, cfg, x[:, t:t + 1], conv_state, ssm_state)
        outs.append(y)
    return jnp.concatenate(outs, 1)
