"""Training launcher: sharded train loop + fault tolerance.

Runs on whatever devices exist (CPU here; the same code path works on a
TPU slice — only the mesh builder changes).  Features exercised:

* pjit/GSPMD sharding from the same rules as the production dry-run;
* deterministic shardable data pipeline (exact resume);
* distributed checkpoint save/restore (atomic manifest publish);
* preemption tolerance: SIGTERM triggers a synchronous final checkpoint;
* straggler watchdog: logs steps slower than ``watchdog_factor`` x the
  running median; after ``--fail-at-step`` (simulated node loss) the
  trainer performs an **elastic restart** — rebuilds a smaller mesh,
  re-lowers, and reshards parameters from the last checkpoint.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import signal
import statistics
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="simulate N devices on CPU (set before jax init)")
    ap.add_argument("--data", type=int, default=0, help="data-axis size")
    ap.add_argument("--model", type=int, default=1, help="model-axis size")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate losing half the data axis at this step "
                         "(elastic restart)")
    ap.add_argument("--watchdog-factor", type=float, default=3.0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import numpy as np
    from repro.configs import get_config, smoke_config
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_host_mesh
    from repro.training import checkpoint as ckpt
    from repro.training import trainer
    from repro.training.data import DataConfig, SyntheticLM
    from repro.training.optimizer import cosine_schedule, make_optimizer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    sched = cosine_schedule(args.lr, warmup=5, total=max(args.steps, 10))
    optimizer = make_optimizer(args.optimizer, sched)

    data_cfg = DataConfig(cfg.vocab_size, args.seq_len, args.global_batch)

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *a: stop.__setitem__("now", True))

    def build(data_axis: int):
        """(Re)build mesh + jitted step for the current healthy device set."""
        mesh = make_host_mesh(data=data_axis, model=args.model)
        step_fn = trainer.make_train_step(
            cfg, optimizer, microbatches=args.microbatches,
            remat=False, clip_norm=1.0)
        state_sds = trainer.abstract_state(cfg, optimizer)
        p_sh = sh.params_shardings(state_sds.params, mesh, cfg)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        return mesh, jit_step, p_sh

    data_axis = args.data or None
    mesh, jit_step, p_sh = build(data_axis)
    dp = mesh.shape["data"]

    start_step = 0
    state = trainer.init_state(cfg, optimizer, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        restored = ckpt.load_latest(args.ckpt_dir, state)
        if restored:
            start_step, state, manifest = restored
            print(f"[resume] step {start_step} from {args.ckpt_dir}")
    state = jax.device_put(state, sh.replicated(mesh))
    data = SyntheticLM(data_cfg, dp_rank=0, dp_world=1,
                       start_step=start_step)

    def save(step, tag=""):
        if not args.ckpt_dir:
            return
        ckpt.save_checkpoint(args.ckpt_dir, step, state,
                             num_shards=max(dp // 4, 1),
                             extra={"tag": tag, "arch": cfg.name})
        print(f"[ckpt] saved step {step} {tag}")

    step_times = []
    t_total = time.time()
    step = start_step
    while step < args.steps:
        if stop["now"]:
            save(step, tag="sigterm")
            print("[preempt] SIGTERM checkpoint written, exiting cleanly")
            return 0
        if step == args.fail_at_step and dp > 1:
            # ---- simulated node failure: elastic restart ----
            print(f"[elastic] step {step}: simulating loss of half the "
                  f"data axis ({dp} -> {dp // 2}); re-meshing + resharding")
            save(step, tag="pre-failure")
            dp_new = dp // 2
            mesh, jit_step, p_sh = build(dp_new)
            dp = dp_new
            # reshard from checkpoint (the surviving hosts reload)
            if args.ckpt_dir:
                _, state2, _ = ckpt.load_latest(args.ckpt_dir, state)
                state = jax.device_put(state2, sh.replicated(mesh))
            args.fail_at_step = -1  # once
        tokens, labels = data.batch(step)
        t0 = time.time()
        state, metrics = jit_step(
            state, (jax.numpy.asarray(tokens), jax.numpy.asarray(labels)))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        step_times.append(dt)
        med = statistics.median(step_times)
        if len(step_times) > 3 and dt > args.watchdog_factor * med:
            print(f"[watchdog] step {step} took {dt:.2f}s "
                  f"(median {med:.2f}s) — straggler detected")
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        step += 1
        data.step = step
        if args.ckpt_dir and step % args.ckpt_every == 0:
            save(step)
    save(args.steps, tag="final")
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_total:.1f}s; final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
