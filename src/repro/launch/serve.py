"""Serving launcher: prefix-shared decode with the CoDec engine.

Generates a synthetic document-QA workload (shared document prefix +
per-request questions), serves it with the chosen attention backend, and
reports TPOT + prefix-cache + memory-pressure statistics.  ``--compare``
runs codec vs. the FlashDecoding baseline back-to-back (the paper's
Fig. 7 setup).  ``--max-pages`` sizes the paged KV pool — undersize it
and the engine preempts-and-recomputes instead of failing;
``--prefill-chunk`` (int or ``auto``) admits long prompts in chunks
interleaved with decode steps.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --smoke --requests 4 --doc-len 256 --max-new 8 --compare

    # memory-pressure demo: tiny pool + chunked prefill
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --smoke --max-pages 24 --prefill-chunk 32
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _chunk(v: str):
    if v in ("none", ""):
        return None
    return v if v == "auto" else int(v)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    from ..kernels import registry
    ap.add_argument("--backend", default="codec-pallas",
                    choices=registry.names())
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--doc-len", type=int, default=256)
    ap.add_argument("--q-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-pages", type=int, default=8192,
                    help="KV pool size in pages; undersizing triggers "
                         "preempt-and-recompute instead of MemoryError")
    ap.add_argument("--prefill-chunk", type=_chunk, default=None,
                    help="prefill token budget per step: int, 'auto' "
                         "(cost-model-driven), or 'none' (whole prompt)")
    ap.add_argument("--reserve-pages", type=int, default=0,
                    help="admission low watermark: free pages kept back "
                         "for decode growth of the running batch")
    ap.add_argument("--max-running", type=int, default=None,
                    help="cap on concurrently admitted requests")
    ap.add_argument("--cascade", action="store_true",
                    help="cascade prefill (DESIGN.md §14): co-admit "
                         "waiting requests sharing forest paths, compute "
                         "shared uncached spans once per group and batch "
                         "the per-request suffix chunks into one dispatch")
    ap.add_argument("--fused", action="store_true",
                    help="fused single-dispatch decode step with async "
                         "dispatch (serving/step_fn.py); falls back to "
                         "the eager path for non-jit-safe backends")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative tree-decoding (DESIGN.md §10): "
                         "self-drafted token trees verified in one "
                         "multi-query dispatch; greedy-only, "
                         "single-device")
    ap.add_argument("--spec-depth", type=int, default=4,
                    help="max draft chain length per branch")
    ap.add_argument("--spec-branch", type=int, default=2,
                    help="max sibling draft branches at the leaf")
    ap.add_argument("--spec-nodes", type=int, default=6,
                    help="total draft nodes per request per step")
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL decode mesh for SPMD sharded serving "
                         "(distributed/; implies --fused, needs a "
                         "shardable backend).  >1 total devices forces "
                         "fake host devices when XLA_FLAGS is unset")
    ap.add_argument("--seq-split-pages", type=int, default=0,
                    help="placement quota: pages a node keeps on one "
                         "data shard before sequence-splitting to the "
                         "next (0 = split only when a shard fills)")
    ap.add_argument("--replicate", action="store_true",
                    help="replication-aware placement: copy hot short "
                         "prefix nodes onto every data shard when the "
                         "merge saving beats the extra read cost, so "
                         "their rows skip the cross-shard POR merge")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the cost model's bandwidth/overhead "
                         "coefficients from measured sharded step times "
                         "(blocks each dispatch to time it)")
    ap.add_argument("--cache", action="store_true",
                    help="persistent cross-request prefix cache: finished "
                         "requests detach but their prefix KV stays "
                         "resident (serves a second wave over the same "
                         "document to show warm-cache admission)")
    ap.add_argument("--cache-ttl", type=int, default=None,
                    help="evict cached nodes untouched for this many "
                         "engine steps (implies --cache)")
    ap.add_argument("--cache-pages", type=int, default=None,
                    help="LRU cap on resident cached pages "
                         "(implies --cache)")
    ap.add_argument("--stream", action="store_true",
                    help="register per-request streaming callbacks and "
                         "report first-token latencies")
    ap.add_argument("--max-steps", type=int, default=0,
                    help="engine step budget (0 = max-new + slack)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request wall-clock deadline in seconds; "
                         "requests past it finish TIMED_OUT with their "
                         "KV released (docs/FAULTS.md)")
    ap.add_argument("--max-queue", type=float, default=None,
                    help="max seconds a request may sit WAITING before "
                         "it times out unadmitted")
    ap.add_argument("--check-every", type=int, default=0,
                    help="run the engine invariant self-check every N "
                         "steps (0 = only after recoveries)")
    ap.add_argument("--inject", default=None,
                    help="fault schedule, e.g. 'dispatch@3*2,"
                         "nan_logits@5:0,stall@8=0.01' or "
                         "'seed:7[:rate]' (serving/faults.py grammar)")
    ap.add_argument("--nan-guard", action="store_true",
                    help="per-row NaN/inf logit guard: poisoned rows "
                         "are quarantined as FAILED instead of "
                         "streaming garbage (single-device only)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the metrics registry + tracing layer "
                         "(docs/OBSERVABILITY.md); on by default since "
                         "its measured TPOT overhead is <3%")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (load in "
                         "Perfetto / chrome://tracing); with --compare "
                         "the backend name is suffixed")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics registry snapshot as "
                         "JSON (schema codec-metrics/1)")
    ap.add_argument("--profile-every", type=int, default=0,
                    help="block on every Nth fused step to split it into "
                         "dispatch/device/host phases (0 = never; "
                         "sampled steps only, async path untouched)")
    ap.add_argument("--report-every", type=int, default=0,
                    help="print a one-line metrics summary every N "
                         "engine steps (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.cache_ttl is not None or args.cache_pages is not None:
        args.cache = True

    from repro.distributed.mesh import parse_mesh
    mesh_d, mesh_m = parse_mesh(args.mesh)
    if mesh_d * mesh_m > 1 and "XLA_FLAGS" not in os.environ:
        # must land before the jax backend initialises (first device use)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={mesh_d * mesh_m}")

    import jax
    import numpy as np
    from repro.configs import get_config, smoke_config
    from repro.distributed.mesh import decode_mesh
    from repro.models import transformer as T
    from repro.serving.engine import DecodeEngine

    mesh = decode_mesh(mesh_d, mesh_m) if mesh_d * mesh_m > 1 else None
    if mesh is not None:
        args.fused = True                 # mesh serving is fused-only

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_layers:
        print("encoder-decoder archs are served via the decoder backbone "
              "only; use a decoder-only arch for the engine demo")
        return 1
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    doc = rng.integers(0, cfg.vocab_size, args.doc_len).tolist()
    prompts = [doc + rng.integers(0, cfg.vocab_size, args.q_len).tolist()
               for _ in range(args.requests)]
    max_steps = args.max_steps or 4 * args.max_new + 16

    spec = None
    if args.speculative:
        from repro.serving.speculation import SpecConfig
        spec = SpecConfig(depth=args.spec_depth, branch=args.spec_branch,
                          max_nodes=args.spec_nodes)

    cache_policy = None
    if args.cache:
        from repro.serving.cache import CachePolicy
        cache_policy = CachePolicy(ttl_steps=args.cache_ttl,
                                   max_pages=args.cache_pages)

    fault_plan = None
    if args.inject:
        from repro.serving.faults import FaultPlan
        fault_plan = FaultPlan.parse(args.inject)

    from repro.core import metrics as metrics_mod
    from repro.serving.telemetry import Telemetry

    def run(backend: str):
        telemetry = None if args.no_telemetry else Telemetry(
            profile_every=args.profile_every)
        eng = DecodeEngine(cfg, params, page_size=args.page_size,
                           num_pages=args.max_pages, backend=backend,
                           max_q=max(args.requests, 8), temperature=0.0,
                           prefill_chunk=args.prefill_chunk,
                           reserve_pages=args.reserve_pages,
                           max_running=args.max_running,
                           cascade=args.cascade,
                           fused=args.fused, mesh=mesh,
                           seq_split_pages=args.seq_split_pages,
                           replicate=args.replicate,
                           calibrate=args.calibrate,
                           speculative=spec, cache=cache_policy,
                           faults=fault_plan, nan_guard=args.nan_guard,
                           check_every=args.check_every,
                           telemetry=telemetry)
        first_tok = {}

        def on_token(rid, tok):
            first_tok.setdefault(rid, time.time())

        # periodic one-line metrics summary: reader-owned snapshot so
        # the per-interval deltas are exact regardless of other readers
        report_prev = [eng.publish_metrics().snapshot()
                       if telemetry is not None else None]

        def report(engine):
            if (args.report_every <= 0
                    or engine.stats["steps"] % args.report_every):
                return
            now = engine.publish_metrics().snapshot()
            d = metrics_mod.delta(now, report_prev[0])
            report_prev[0] = now
            line = (f"    [step {engine.stats['steps']}] "
                    f"+{d['tokens_generated']['value']:.0f} tok, "
                    f"run/wait {d['running']['value']:.0f}"
                    f"/{d['waiting']['value']:.0f}, "
                    f"pool {d['pool_occupancy']['value']:.0%}, "
                    f"step p50 "
                    f"{1000 * metrics_mod.hist_quantile(d['step_s'], 0.5):.1f} ms")
            if d["ttft_s"]["count"]:
                line += (f", ttft p50 {1000 * metrics_mod.hist_quantile(d['ttft_s'], 0.5):.0f} ms")
            if cache_policy is not None:
                line += f", cache hit {d['cache_hit_rate']['value']:.0%}"
            print(line)

        on_step = report if (telemetry is not None
                             and args.report_every > 0) else None
        t0 = time.time()
        for p in prompts:
            eng.add_request(p, max_new=args.max_new,
                            on_token=on_token if args.stream else None,
                            deadline_s=args.deadline,
                            max_queue_s=args.max_queue)
        t_prefill = time.time() - t0
        t0 = time.time()
        try:
            outs = eng.run(max_steps, on_step=on_step)
        except KeyboardInterrupt:
            # graceful shutdown: cancel everything in flight, release
            # all KV, verify nothing leaked, report what was running
            print("\ninterrupted — draining engine")
            summary = eng.shutdown()
            st = eng.stats
            n_done = sum(1 for q in eng.requests.values()
                         if q.state == "done")
            print(f"    shutdown: {summary['requests']} requests "
                  f"({n_done} done, {st['cancelled']} cancelled, "
                  f"{st['timed_out']} timed out, {st['failed']} failed), "
                  f"{summary['used_pages']} pages leaked, "
                  f"{st['faults_injected']} faults injected, "
                  f"{st['callback_errors']} callback errors")
            raise SystemExit(130)
        t_decode = time.time() - t0
        steps = eng.stats["steps"]
        io = eng.forest.codec_io_bytes(cfg.num_kv_heads, cfg.head_dim)
        io_flash = eng.forest.flash_io_bytes(cfg.num_kv_heads, cfg.head_dim)
        print(f"[{backend}] prefill {t_prefill:.2f}s "
              f"({eng.stats['prefill_tokens']} new tokens; prefix reuse "
              f"saved {sum(len(p) for p in prompts) - eng.stats['prefill_tokens']}), "
              f"decode {t_decode:.2f}s / {steps} steps "
              f"= TPOT {1000 * t_decode / max(steps, 1):.1f} ms, "
              f"plan {eng.stats['plan_time']:.3f}s "
              f"({eng.stats['replans']} replans)")
        print(f"    KV IO per step: codec {io / 1e6:.2f} MB vs "
              f"per-request {io_flash / 1e6:.2f} MB "
              f"({io_flash / max(io, 1):.1f}x reduction, "
              f"mean sharing degree {eng.forest.mean_sharing_degree():.1f})")
        st = eng.stats
        if eng.fused:
            print(f"    fused step: {st['fused_calls']} dispatches, "
                  f"{eng.fused_cache_size} compiles "
                  f"({len(eng.bucket_signatures)} shape buckets), "
                  f"{st['token_flushes']} token syncs, dispatch "
                  f"{st['decode_dispatch_time']:.3f}s / sync "
                  f"{st['decode_sync_time']:.3f}s")
        if eng.spec is not None:
            tok_total = sum(len(q.generated)
                            for q in eng.requests.values())
            print(f"    speculation: {st['spec_steps']} verify dispatches "
                  f"for {tok_total} committed tokens; "
                  f"{st['spec_accepted']}/{st['spec_proposed']} drafts "
                  f"accepted (+{st['spec_accepted'] / max(st['spec_steps'], 1):.2f} "
                  f"extra tokens/dispatch, "
                  f"{st['spec_draft_stalls']} page stalls)")
        peak = eng.pool.allocator.peak_used
        shard_occ = ""
        if mesh is not None:
            occ = eng.pool.shard_occupancy()
            shard_occ = (" | shard occupancy "
                         + "/".join(f"{o:.0%}" for o in occ))
            # max over per-window plans (a node split across shards
            # appears in every window's plan); last epoch's snapshot
            splits = max((sp.seq_splits
                          for sp in eng._sharded_plans.values()),
                         default=0)
            shard_occ += f", {splits} seq-split nodes (last plan)"
            last_sp = next(iter(eng._sharded_plans.values()), None)
            if last_sp is not None:
                ls = last_sp.stats()
                shard_occ += (f", {ls['replicated_nodes']} replicated "
                              f"nodes / {ls['merge_row_count']} merge "
                              f"rows (last plan)")
            if eng.cost_model.calibrated:
                hw = eng.cost_model.hw
                shard_occ += (f" | calibrated hw: hbm "
                              f"{hw.hbm_bw / 1e9:.0f} GB/s, ici "
                              f"{hw.ici_bw / 1e9:.1f} GB/s "
                              f"({st['calibrations']} fits)")
        print(f"    memory pressure: peak {peak}/{eng.pool.num_pages} pages "
              f"({100 * peak / eng.pool.num_pages:.0f}%), "
              f"{st['preempted']} preemptions, {st['reclaimed']} reclaims, "
              f"{st['recompute_tokens']} recomputed tokens, "
              f"{st['prefill_chunks']} prefill chunks{shard_occ}")
        if eng.injector is not None or args.nan_guard or args.deadline:
            ended = {s: sum(1 for q in eng.requests.values()
                            if q.state == s)
                     for s in ("done", "cancelled", "timed_out", "failed")}
            fired = (dict(eng.injector.fired)
                     if eng.injector is not None else {})
            print(f"    faults: {st['faults_injected']} injected "
                  f"{fired}, {st['dispatch_failures']} dispatch "
                  f"failures / {st['dispatch_recoveries']} recovered, "
                  f"{st['nan_rows']} NaN rows quarantined, "
                  f"{st['callback_errors']} callback errors, "
                  f"{st['invariant_checks']} self-checks | outcomes "
                  f"{ended}")
        if args.stream and first_tok:
            if telemetry is not None:
                # registry is the source of truth: TTFT measured from
                # add_request to the token landing host-side
                h = telemetry.metrics["ttft_s"]
                print(f"    streaming: first token after "
                      f"{1000 * h.min:.0f}–{1000 * h.max:.0f} ms "
                      f"(p50 {1000 * h.quantile(0.5):.0f} ms, "
                      f"{h.count} streams)")
            else:
                ttfts = sorted(1000 * (first_tok[r] - t0)
                               for r in first_tok)
                print(f"    streaming: first token after "
                      f"{ttfts[0]:.0f}–{ttfts[-1]:.0f} ms "
                      f"({len(first_tok)} streams)")
        if eng.cache is not None:
            # second wave: new questions over the same document served
            # by the SAME engine — admission hits the resident prefix
            warm = [doc + rng.integers(0, cfg.vocab_size,
                                       args.q_len).tolist()
                    for _ in range(args.requests)]
            t0w = time.time()
            for p in warm:
                eng.add_request(p, max_new=args.max_new)
            eng.run(max_steps)
            t_warm = time.time() - t0w
            cs = eng.cache.stats
            last = eng.step_stats[-1] if eng.step_stats else {}
            print(f"    prefix cache: warm wave {t_warm:.2f}s, hit rate "
                  f"{eng.cache.hit_rate:.0%} ({cs['hits']} hits / "
                  f"{cs['misses']} misses, {cs['hit_tokens']} of "
                  f"{cs['lookup_tokens']} prompt tokens cached), "
                  f"resident {last.get('cache_resident_pages', 0)} pages "
                  f"({last.get('cache_resident_bytes', 0) / 1e6:.1f} MB), "
                  f"{cs['evicted_nodes']} nodes / {cs['evicted_pages']} "
                  f"pages evicted")
        unfinished = [r for r, q in eng.requests.items()
                      if len(q.generated) < q.max_new and not q.finished]
        if unfinished:
            print(f"    WARNING: {len(unfinished)} requests unfinished "
                  f"within {max_steps} steps: {unfinished}")
        if telemetry is not None:
            snap = eng.publish_metrics().snapshot()
            print(f"    telemetry: {snap['requests_done']['value']:.0f} "
                  f"done, {snap['tokens_generated']['value']:.0f} tokens, "
                  f"tpot p50 "
                  f"{1000 * metrics_mod.hist_quantile(snap['tpot_s'], 0.5):.1f} ms, "
                  f"e2e p50 "
                  f"{1000 * metrics_mod.hist_quantile(snap['e2e_s'], 0.5):.0f} ms, "
                  f"{len(telemetry.trace_events())} trace events")
            suffix = f".{backend}" if args.compare else ""
            if args.trace_out:
                path = args.trace_out + suffix
                telemetry.export_trace(path)
                print(f"    trace -> {path}")
            if args.metrics_out:
                path = args.metrics_out + suffix
                eng.export_metrics(path)
                print(f"    metrics -> {path}")
        return outs

    if args.compare:
        # flash (per-request baseline) is not shardable; on a mesh the
        # comparison pair is the two shardable codec backends instead
        other = "codec-xla" if mesh is not None else "flash"
        o1 = run("codec-pallas")
        o2 = run(other)
        match = o1 == o2
        print(f"outputs codec == {other}: {match}")
        return 0 if match else 1
    run(args.backend)
    return 0


if __name__ == "__main__":
    sys.exit(main())
