"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective bytes;
we parse the per-device HLO text, sum the shard-level result sizes of
every collective op, and convert to *link seconds* with the standard
ring-algorithm byte multipliers:

    all-reduce        2 (n-1)/n x s     (reduce-scatter + all-gather)
    all-gather          (n-1)/n x S_out
    reduce-scatter      (n-1)/n x S_in
    all-to-all          (n-1)/n x s
    collective-permute  1.0     x s

where n = replica-group size and s = per-device operand bytes.  The
roofline collective term is then ``sum(bytes_on_link) / link_bw`` —
per-device wire time, matching the `collective_bytes / (chips*link_bw)`
formulation (collective_bytes there being the all-chip total).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )


def _shape_bytes(type_str: str, result_half_only: bool = False) -> int:
    shapes = [s for s in _SHAPE_RE.findall(type_str)
              if s[0] in _DTYPE_BYTES]
    if result_half_only and len(shapes) > 1:
        # async '-start' ops carry (operands..., results...) tuples; only
        # the result half is traffic.
        shapes = shapes[len(shapes) // 2:]
    total = 0
    for dtype, dims in shapes:
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [num_groups, group_size]<=[...]
        return int(m.group(2))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    count: Dict[str, int]
    result_bytes: Dict[str, float]   # per-device result-shard bytes
    link_bytes: Dict[str, float]     # ring-multiplier adjusted wire bytes

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    def link_seconds(self, link_bw: float) -> float:
        return self.total_link_bytes / link_bw


def collect_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    count: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    result_bytes: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    link_bytes: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, op, start = m.group(1), m.group(2), m.group(3)
        # '-done' ops don't match (no '('-following result type pattern);
        # async '-start' counted once here.
        s = _shape_bytes(type_str, result_half_only=bool(start))
        if s == 0:
            continue
        n = max(_group_size(line, total_devices), 1)
        if n == 1:
            continue  # degenerate group: no traffic
        frac = (n - 1) / n
        if op == "all-reduce":
            wire = 2.0 * frac * s
        elif op == "collective-permute":
            wire = float(s)
        else:  # all-gather (s = full out), reduce-scatter, all-to-all
            wire = frac * s
        count[op] += 1
        result_bytes[op] += s
        link_bytes[op] += wire
    return CollectiveStats(count, result_bytes, link_bytes)


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def extract_memory(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    fields = ["argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"]
    return {f: float(getattr(ma, f, 0)) for f in fields}
