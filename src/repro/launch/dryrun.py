import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the
device count at first init), and must not leak into tests/benchmarks —
which is why this module is only ever run as a CLI.

Per cell we record (to ``experiments/dryrun/<arch>__<shape>__<mesh>.json``):

* ``compiled.memory_analysis()``  — per-device argument/output/temp bytes
  (proves the sharding fits, or honestly reports when a config exceeds
  a 16 GiB v5e HBM);
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline;
* collective traffic parsed from the partitioned HLO (hlo_stats);
* derived roofline terms (compute / memory / collective seconds) and
  MODEL_FLOPS = 6*N*D (6*N_active*D for MoE).

Usage::

    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all            # subprocess per cell
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # B/s
LINK_BW = 50e9          # B/s per ICI link
HBM_BYTES = 16 * 2**30  # 16 GiB


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             microbatches: int = 1, remat: bool = True,
             tag: str = "", ce_impl: str = "gather",
             fsdp: bool = True, donate_cache: bool = False,
             moe_groups: int = 1) -> dict:
    import jax
    from repro.configs import get_config, shape_supported, skip_reason
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "multi_pod": multi_pod, "tag": tag,
           "microbatches": microbatches, "remat": remat,
           "ce_impl": ce_impl, "fsdp": fsdp,
           "donate_cache": donate_cache, "moe_groups": moe_groups}
    if not shape_supported(cfg, shape_name):
        rec.update(status="skip", reason=skip_reason(cfg, shape_name))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    opts = dict(microbatches=microbatches, remat=remat, ce_impl=ce_impl,
                fsdp=fsdp, moe_groups=moe_groups)
    cell = input_specs(arch, shape_name, mesh, **opts)
    # donate the decode cache (serve_step args: params, tokens, cache,
    # cache_len) / the train state — real deployments alias these
    donate = ()
    if donate_cache:
        donate = (2,) if cell.kind == "decode" else (0,)
    with mesh:
        lowered = jax.jit(cell.step_fn,
                          donate_argnums=donate).lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost_raw = hlo_stats.extract_cost(compiled)
    mem = hlo_stats.extract_memory(compiled)
    coll = hlo_stats.collect_collectives(compiled.as_text(), chips)

    # --- exact cost accounting -----------------------------------------
    # XLA's cost analysis counts while-loop (scan) bodies ONCE, so the
    # full-model numbers above undercount by the period trip count.  We
    # compile the same cell UNROLLED at k=1 and k=2 periods; every cost
    # is affine in k, so extrapolate to the real period count.
    P = cell.cfg.num_periods
    t1 = time.time()
    costs_k, colls_k = [], []
    for k in (1, 2):
        ck = input_specs(arch, shape_name, mesh, num_periods=k,
                         unroll=True, **opts)
        with mesh:
            lk = jax.jit(ck.step_fn, donate_argnums=donate).lower(*ck.args)
            comp_k = lk.compile()
        costs_k.append(hlo_stats.extract_cost(comp_k))
        colls_k.append(hlo_stats.collect_collectives(comp_k.as_text(),
                                                     chips))
        del lk, comp_k
    t_extrap = time.time() - t1

    def affine(v1, v2):
        return v1 + (P - 1) * (v2 - v1)

    cost = {key: affine(costs_k[0][key], costs_k[1][key])
            for key in costs_k[0]}
    coll_link = {op: affine(colls_k[0].link_bytes[op],
                            colls_k[1].link_bytes[op])
                 for op in colls_k[0].link_bytes}
    coll_count = {op: round(affine(colls_k[0].count[op],
                                   colls_k[1].count[op]))
                  for op in colls_k[0].count}
    total_link_bytes = sum(coll_link.values())

    # roofline terms, per-device seconds (post-SPMD the compiled module
    # is the per-partition program, so cost_analysis is per-chip —
    # equal to HLO_total / chips in the assignment's formulation).
    t_compute = cost["flops"] / PEAK_FLOPS
    t_memory = cost["bytes_accessed"] / HBM_BW
    t_collective = total_link_bytes / LINK_BW

    N = cfg.param_count()
    N_act = cfg.active_param_count()
    sh = cell.shape
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 6.0 * N_act * tokens
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 2.0 * N_act * tokens
    else:
        tokens = sh.global_batch  # one token per request
        model_flops = 2.0 * N_act * tokens

    per_dev_bytes = (mem["argument_size_in_bytes"]
                     + mem["output_size_in_bytes"]
                     - mem["alias_size_in_bytes"]
                     + mem["temp_size_in_bytes"])
    dominant = max([("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective)], key=lambda kv: kv[1])[0]
    rec.update(
        status="ok", chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        extrap_s=round(t_extrap, 2),
        cost=cost, cost_raw_scan=cost_raw, memory=mem,
        collectives={"count": coll_count,
                     "link_bytes": coll_link,
                     "raw_scan_count": coll.count,
                     "raw_scan_link_bytes": coll.link_bytes},
        roofline={
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_collective,
            "dominant": dominant,
            "bound_s": max(t_compute, t_memory, t_collective),
        },
        model_flops=model_flops,
        model_flops_per_chip=model_flops / chips,
        useful_flops_ratio=(model_flops / chips) / max(cost["flops"], 1.0),
        per_device_bytes=per_dev_bytes,
        fits_hbm=bool(per_dev_bytes <= HBM_BYTES),
    )
    return rec


def cell_filename(arch, shape, mesh_name, tag=""):
    suffix = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh_name}{suffix}.json".replace("/", "_")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ce-impl", default="gather",
                    choices=["gather", "onehot"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--donate-cache", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs import ASSIGNED_ARCHS, PAPER_ARCH
        from repro.configs.shapes import SHAPES
        archs = ASSIGNED_ARCHS + [PAPER_ARCH]
        meshes = [False, True]   # --all always covers both meshes
        failures = []
        for arch in archs:
            for shape in SHAPES:
                for mp in meshes:
                    mesh_name = "2x16x16" if mp else "16x16"
                    path = os.path.join(
                        args.out, cell_filename(arch, shape, mesh_name,
                                                args.tag))
                    if os.path.exists(path):
                        print(f"[skip-cached] {arch} {shape} {mesh_name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out",
                           args.out, "--tag", args.tag,
                           "--microbatches", str(args.microbatches)]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.no_remat:
                        cmd.append("--no-remat")
                    print(f"[run] {arch} {shape} {mesh_name} ...",
                          flush=True)
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_name))
        if failures:
            print("FAILED cells:", failures)
            return 1
        print("all cells OK")
        return 0

    assert args.arch and args.shape, "--arch and --shape required"
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                       microbatches=args.microbatches,
                       remat=not args.no_remat, tag=args.tag,
                       ce_impl=args.ce_impl, fsdp=not args.no_fsdp,
                       donate_cache=args.donate_cache,
                       moe_groups=args.moe_groups)
    except Exception:
        traceback.print_exc()
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
               "tag": args.tag, "status": "error",
               "error": traceback.format_exc()}
        path = os.path.join(args.out, cell_filename(
            args.arch, args.shape, mesh_name, args.tag))
        with open(path + ".err", "w") as f:
            json.dump(rec, f, indent=1)
        return 1
    path = os.path.join(args.out, cell_filename(
        args.arch, args.shape, mesh_name, args.tag))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"{args.arch} {args.shape} {mesh_name}: OK "
              f"compute={r['t_compute_s']:.3e}s memory={r['t_memory_s']:.3e}s "
              f"collective={r['t_collective_s']:.3e}s "
              f"dominant={r['dominant']} "
              f"per_dev={rec['per_device_bytes']/2**30:.2f}GiB "
              f"fits_hbm={rec['fits_hbm']} "
              f"useful={rec['useful_flops_ratio']:.3f}")
    else:
        print(f"{args.arch} {args.shape} {mesh_name}: "
              f"{rec['status'].upper()} {rec.get('reason','')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
