"""Partitioning rules: param/batch/cache PartitionSpecs for every arch.

Scheme (per pod: mesh axes ``data`` x ``model``; multi-pod adds ``pod``):

* **TP over `model`** — attention heads, FFN hidden, expert dim (EP),
  Mamba inner channels, vocab (embed/lm_head).
* **FSDP over `data`** — the other large axis of every weight matrix is
  sharded over `data`; GSPMD all-gathers weights on use (ZeRO-3) and
  reduce-scatters gradients.
* **DP over `pod` (+`data`)** — batch dims of activations; cross-pod
  traffic is only the gradient all-reduce.
* Decode KV caches shard batch over `data` and the *sequence* dim over
  `model` (flash-decoding-style split-K: each device computes a partial
  softmax over its KV shard; the merge is the same LSE algebra as CoDec's
  POR).  For global_batch=1 (long_500k) the sequence dim takes all axes.

Rules are path-based over the param pytree; stacked period params
("blocks") get a leading replicated axis.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspec(path_str: str, ndim: int, cfg: ModelConfig,
                fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf."""
    parts = path_str.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    gparent = parts[-3] if len(parts) > 2 else ""
    dp = "data" if fsdp else None

    def spec(*axes):
        # prepend replicated leading axes (e.g. the stacked period dim)
        lead = ndim - len(axes)
        return P(*([None] * lead + list(axes)))

    # embeddings / unembedding: vocab over DATA, d_model over model.
    # (§Perf iteration: the vocab-over-model layout made every token
    # gather a collective-permute chain and the tied unembed an
    # all-gather — transposing the spec cut the qwen3-4b train cell's
    # collective term 2.2x and its memory term 1.6x.)
    if name == "embed":
        return spec(dp, "model")
    if name == "lm_head":
        return spec("model", dp)

    # attention projections
    if parent in ("wq", "wk", "wv") or (name in ("wq", "wk", "wv")):
        if name == "b":
            return spec("model")
        return spec(dp, "model")
    if parent == "wo" and gparent in ("attn", "xattn"):
        if name == "b":
            return spec(None)
        return spec("model", dp)

    # MoE: experts over model (EP)
    if name == "router":
        return spec(dp, None)
    if parent == "ffn" and name == "wi" and ndim >= 3:
        return spec("model", dp, None)
    if parent == "ffn" and name == "wo" and ndim >= 3:
        return spec("model", None, dp)

    # dense MLP
    if gparent == "ffn" and parent == "wi":
        return spec(dp, "model")
    if gparent == "ffn" and parent == "wo":
        return spec("model", dp)

    # mamba
    if parent == "in_proj":
        return spec(dp, "model")
    if parent == "out_proj":
        return spec("model", dp)
    if name == "conv_w":
        return spec(None, "model")
    if name in ("conv_b", "norm") and parent == "mamba":
        return spec("model")
    if name in ("A_log", "D", "dt_bias"):
        return spec("model")

    # norms and everything else small: replicated
    return P()


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def legalize(spec: P, shape, mesh) -> P:
    """Drop spec axes that do not evenly divide the dimension (explicit
    input shardings must tile exactly; GSPMD pads only intermediates)."""
    out = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def params_shardings(params_like: PyTree, mesh, cfg: ModelConfig,
                     fsdp: bool = True) -> PyTree:
    """NamedSharding pytree matching ``params_like`` (arrays or SDS)."""
    def one(path, leaf):
        ps = param_pspec(_path_str(path), len(leaf.shape), cfg, fsdp)
        return NamedSharding(mesh, legalize(ps, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_like)


# --------------------------------------------------------------------- #
# batch / activation shardings
# --------------------------------------------------------------------- #
def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_sharding(mesh, ndim: int, global_batch: int) -> NamedSharding:
    """Shard the leading batch dim over (pod, data) when divisible."""
    axes = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % size != 0 or global_batch < size:
        # fall back to the largest prefix of the dp axes that divides B
        for cut in range(len(axes), 0, -1):
            sz = int(np.prod([mesh.shape[a] for a in axes[:cut]]))
            if global_batch % sz == 0 and global_batch >= sz:
                axes = axes[:cut]
                break
        else:
            axes = ()
    spec = [axes if axes else None] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache_like: PyTree, mesh, cfg: ModelConfig,
                    global_batch: int) -> PyTree:
    """Decode-cache shardings: batch->data, seq->model (split-K decode).

    For batch==1 (long-context) the sequence dim takes every mesh axis.
    """
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    batch_ok = global_batch % dp_size == 0 and global_batch >= dp_size

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        ndim = len(leaf.shape)
        lead = [None] * (ndim - _tail_rank(name))
        if name in ("k", "v", "xk", "xv"):
            # head-major (..., B, hkv, L, hd)
            if batch_ok:
                spec = lead + [dp if len(dp) > 1 else dp[0], None,
                               "model", None]
            else:
                seq_axes = tuple(list(dp) + ["model"])
                spec = lead + [None, None, seq_axes, None]
        elif name == "conv":
            # (..., B, K-1, conv_dim)
            spec = lead + [dp[0] if (batch_ok and dp) else None, None,
                           "model"]
        elif name == "ssm":
            # (..., B, H, P, S)
            spec = lead + [dp[0] if (batch_ok and dp) else None, "model",
                           None, None]
        else:
            spec = [None] * ndim
        return NamedSharding(mesh, legalize(P(*spec), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_like)


def paged_pool_spec(mesh, n_kv: int) -> P:
    """PartitionSpec for the serving engine's paged KV pool
    ``(n_attn, page_rows, page, n_kv, head_dim)``.

    Page rows shard over ``data`` (each data-shard owns a contiguous
    block of pages incl. its trash row), KV heads over ``model`` — the
    same head axis the TP param rules put on ``model``, so q/k/v head
    slices and pool head slices line up device-for-device.  Heads stay
    replicated when they do not divide the axis (``model`` = 1 meshes,
    odd head counts)."""
    # the spec is kept in shard_map's normal form — size-1 axes dropped,
    # trailing Nones trimmed: PartitionSpec compares structurally in jit
    # signatures, and a canonical-vs-emitted mismatch would recompile
    # the fused step on its second dispatch
    shape = dict(mesh.shape)
    data = "data" if shape.get("data", 1) > 1 else None
    model = shape.get("model", 1)
    heads = "model" if model > 1 and n_kv % model == 0 else None
    spec = [None, data, None, heads, None]
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def _tail_rank(name: str) -> int:
    return {"k": 4, "v": 4, "xk": 4, "xv": 4, "conv": 3, "ssm": 4}.get(name, 0)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def with_sharding(tree_like: PyTree, shardings: PyTree) -> PyTree:
    """Attach shardings to a ShapeDtypeStruct pytree (for .lower())."""
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        tree_like, shardings)
