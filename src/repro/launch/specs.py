"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Returns the step function + fully-sharded abstract inputs, so the
dry-run is a pure ``jit(step).lower(*specs).compile()`` — no allocation.

``decode_*``/``long_*`` shapes lower ``serve_step`` (one new token
against a dense seq_len cache); ``prefill_*`` lowers a last-logit
forward; ``train_*`` lowers the full train step (fwd+bwd+optimizer).
Frontend stubs: [audio] supplies precomputed encoder frame embeddings,
[vlm] supplies prefix patch embeddings, per the assignment spec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, shape_supported, skip_reason
from ..configs.base import ModelConfig
from ..configs.shapes import SHAPES, InputShape
from ..models import transformer as T
from ..training import optimizer as opt_mod
from ..training import trainer
from . import sharding as sh

# archs whose optimizer state must be factored to fit HBM
ADAFACTOR_ARCHS = {"kimi-k2-1t-a32b", "llava-next-34b", "qwen1.5-32b",
                   "jamba-v0.1-52b", "llama4-scout-17b-a16e"}


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: InputShape
    step_fn: Callable
    args: Tuple            # ShapeDtypeStructs with shardings attached
    kind: str              # train | prefill | decode
    cfg: ModelConfig


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _extras_fn(cfg: ModelConfig, mesh, batch: int) -> Optional[Callable]:
    """Stub frontend inputs as a function of the token batch (jit-safe)."""
    if cfg.frontend == "vision":
        def fn(tokens):
            B = tokens.shape[0]
            return {"prefix_embeds": jnp.zeros(
                (B, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype))}
        return fn
    if cfg.frontend == "audio":
        def fn(tokens):
            B = tokens.shape[0]
            return {"encoder_embeds": jnp.zeros(
                (B, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype))}
        return fn
    return None


def make_optimizer_for(arch: str, cfg: ModelConfig):
    kind = "adafactor" if arch in ADAFACTOR_ARCHS else "adamw"
    sched = opt_mod.cosine_schedule(3e-4, warmup=100, total=10000)
    return opt_mod.make_optimizer(kind, sched), kind


def reduced_config(cfg: ModelConfig, num_periods: int) -> ModelConfig:
    """Same arch with k periods (remainder layers kept): used by the
    dry-run's cost extrapolation (cost is affine in the period count)."""
    return dataclasses.replace(
        cfg, num_layers=num_periods * cfg.period + cfg.remainder_layers)


def input_specs(arch: str, shape_name: str, mesh, *,
                microbatches: int = 1, remat: bool = True,
                num_periods: Optional[int] = None,
                unroll: bool = False, ce_impl: str = "gather",
                fsdp: bool = True, moe_groups: int = 1) -> CellSpec:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_supported(cfg, shape_name):
        raise ValueError(
            f"{arch} skips {shape_name}: {skip_reason(cfg, shape_name)}")
    if num_periods is not None:
        cfg = reduced_config(cfg, num_periods)
    if moe_groups > 1 and cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_groups=moe_groups)

    # activate activation-sharding constraints for this mesh (the step
    # functions built below trace layers.hint against it)
    from ..models import layers as L_mod
    L_mod.set_activation_mesh(mesh)

    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        optimizer, _ = make_optimizer_for(arch, cfg)
        state_sds = trainer.abstract_state(cfg, optimizer)
        p_shardings = sh.params_shardings(state_sds.params, mesh, cfg,
                                          fsdp=fsdp)
        opt_shardings = _opt_shardings(state_sds.opt_state, p_shardings,
                                       mesh)
        state = trainer.TrainState(
            _sds((), jnp.int32, sh.replicated(mesh)),
            sh.with_sharding(state_sds.params, p_shardings),
            opt_shardings)
        bsh = sh.batch_sharding(mesh, 2, B)
        tokens = _sds((B, S), jnp.int32, bsh)
        labels = _sds((B, S), jnp.int32, bsh)
        step_fn = trainer.make_train_step(
            cfg, optimizer, microbatches=microbatches, remat=remat,
            extras_fn=_extras_fn(cfg, mesh, B), unroll=unroll,
            ce_impl=ce_impl)
        return CellSpec(arch, shape, step_fn, (state, (tokens, labels)),
                        "train", cfg)

    params_sds = jax.eval_shape(
        lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
    p_shardings = sh.params_shardings(params_sds, mesh, cfg, fsdp=fsdp)
    params = sh.with_sharding(params_sds, p_shardings)

    if shape.kind == "prefill":
        bsh = sh.batch_sharding(mesh, 2, B)
        tokens = _sds((B, S), jnp.int32, bsh)
        step_fn = trainer.make_prefill_step(cfg, _extras_fn(cfg, mesh, B),
                                            unroll=unroll)
        return CellSpec(arch, shape, step_fn, (params, tokens),
                        "prefill", cfg)

    # decode: one token against a dense cache of S tokens
    enc_len = cfg.frontend_seq if cfg.cross_attention else 0
    cache_sds = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, enc_len=enc_len))
    c_shardings = sh.cache_shardings(cache_sds, mesh, cfg, B)
    cache = sh.with_sharding(cache_sds, c_shardings)
    bsh = sh.batch_sharding(mesh, 2, B)
    tokens = _sds((B, 1), jnp.int32, bsh)
    cache_len = _sds((B,), jnp.int32, sh.batch_sharding(mesh, 1, B))
    step_fn = trainer.make_serve_step(cfg, unroll=unroll)
    return CellSpec(arch, shape, step_fn, (params, tokens, cache, cache_len),
                    "decode", cfg)


def _opt_shardings(opt_like, p_shardings, mesh):
    """SDS-with-shardings for optimizer state: reuse the param spec where
    the slot mirrors the param (adamw m/v), drop factored axes for
    adafactor vr/vc, replicate scalars."""

    def walk(s, p_sh):
        # adafactor leaf-slot dicts {vr, vc} / {v}
        if isinstance(s, dict) and set(s) <= {"vr", "vc", "v"}:
            out = {}
            for k2, leaf in s.items():
                ps = list(p_sh.spec)
                ps += [None] * (len(ps) + 2)      # pad so slicing is safe
                if k2 == "vr":      # param shape[:-1]
                    spec = ps[:len(leaf.shape)]
                elif k2 == "vc":    # param shape[:-2] + shape[-1:]
                    n = len(leaf.shape)
                    spec = ps[:n - 1] + [ps[n]] if n >= 1 else []
                else:               # v mirrors the param
                    spec = ps[:len(leaf.shape)]
                out[k2] = _sds(leaf.shape, leaf.dtype,
                               jax.sharding.NamedSharding(
                                   mesh, jax.sharding.PartitionSpec(*spec)))
            return out
        if isinstance(s, dict):
            return {k2: walk(v2, p_sh[k2]) for k2, v2 in s.items()}
        if isinstance(s, (list, tuple)):
            return type(s)(walk(v2, p_sh[i]) for i, v2 in enumerate(s))
        return _sds(s.shape, s.dtype, p_sh)   # mirrors a param leaf

    out = {}
    for k, v in opt_like.items():
        if k in ("m", "v", "slots"):
            out[k] = walk(v, p_shardings)
        else:
            out[k] = jax.tree.map(
                lambda s: _sds(s.shape, s.dtype, sh.replicated(mesh)), v)
    return out


def _flat(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_key(p), v) for p, v in leaves]


def _key(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)
