"""Production mesh builders.

A v5e pod is 16x16 = 256 chips; the production target is 2 pods = 512.
Within a pod the mesh is (data=16, model=16): `model` maps to one torus
dimension (TP/EP collectives stay on fast ICI rings), `data` to the
other.  Multi-pod adds a leading `pod` axis — pure DP across pods so the
only cross-DCN collective is the once-per-step gradient all-reduce.

Defined as functions (never module-level constants) so importing this
module cannot touch jax device state before the launcher has configured
``xla_force_host_platform_device_count``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: Optional[int] = None, model: Optional[int] = None):
    """Small mesh over whatever devices exist (tests / CPU trainers)."""
    n = jax.device_count()
    if data is None and model is None:
        model = 1
        data = n
    elif data is None:
        data = n // model
    elif model is None:
        model = n // data
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axis_sizes(mesh) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in mesh.axis_names)
