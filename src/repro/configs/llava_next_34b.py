"""llava-next-34b — VLM backbone, anyres vision frontend stubbed
[hf:llava-hf].  input_specs() supplies 576 precomputed patch embeddings
prepended to the token sequence.
"""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    frontend="vision", frontend_seq=576,
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=False,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "to sub-quadratic archs"),),
)
