"""gemma3-1b — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt].  Period of 6: five local layers then one
global; window 512.  26 layers = 4 periods + 2 local remainder.
"""
from .base import LayerKind, ModelConfig

_PERIOD = tuple(LayerKind("attn_local" if i < 5 else "attn", "mlp")
                for i in range(6))

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    sliding_window=512, mlp_act="geglu", embed_scale=True,
    layer_pattern=_PERIOD,
    tie_embeddings=True,
    # long_500k runs: local layers cap KV at the 512-token window; the
    # 1-in-6 global layers read the sequence-sharded 500k KV (decode is
    # linear in context, and window pruning drops 5/6 of the reads).
)
