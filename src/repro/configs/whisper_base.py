"""whisper-base — encoder-decoder with audio frontend stub
[arXiv:2212.04356].  6 encoder + 6 decoder layers; the conv/mel frontend
is stubbed: input_specs() supplies 1500 precomputed frame embeddings.
"""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, cross_attention=True,
    frontend="audio", frontend_seq=1500,
    pos_embedding="absolute", norm="layer", mlp_act="gelu",
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=True,
    skip_shapes=(("long_500k", "pure full attention decoder; 500k decode "
                  "assigned to sub-quadratic archs"),),
)
