"""mamba2-2.7b — SSD, attention-free [arXiv:2405.21060]."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256, ssm_conv=4,
    layer_pattern=(LayerKind("mamba", "none"),),
    tie_embeddings=True,
    # attention-free: every shape runs; decode is an O(1) state update.
)
