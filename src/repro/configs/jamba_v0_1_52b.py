"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Period of 8: attention at slot 4, MoE on odd slots.
"""
from .base import LayerKind, ModelConfig

_PERIOD = tuple(
    LayerKind("attn" if i == 4 else "mamba",
              "moe" if i % 2 == 1 else "mlp")
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    num_experts=16, num_experts_per_tok=2, capacity_factor=1.25,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256, ssm_conv=4,
    layer_pattern=_PERIOD,
    tie_embeddings=False,
    # hybrid: long_500k runs (mamba layers O(1); 4 attn layers read paged KV)
)
