"""qwen2.5-14b — GQA kv=8, QKV bias [hf:Qwen]."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064,
    qkv_bias=True,
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=False,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "to sub-quadratic archs"),),
)
