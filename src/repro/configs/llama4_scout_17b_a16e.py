"""llama4-scout-17b-16e — MoE top-1, early fusion [hf:meta-llama]."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=16, num_experts_per_tok=1, capacity_factor=1.25,
    layer_pattern=(LayerKind("attn", "moe"),),
    tie_embeddings=False,
    skip_shapes=(("long_500k", "full attention (iRoPE chunking not "
                  "modelled); 500k decode assigned to sub-quadratic archs"),),
)
