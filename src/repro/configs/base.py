"""Model/config schema shared by all architectures.

A config fully determines the model graph; ``layer_pattern`` describes one
repeating *period* of heterogeneous layers so the forward pass can scan
over periods (keeping HLO size O(period), essential for 512-device
compiles of 48-64 layer models).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """One sub-layer slot in the repeating period."""
    mixer: str = "attn"       # attn | attn_local | mamba
    ffn: str = "mlp"          # mlp | moe | none (mamba blocks carry no FFN in mamba2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0           # tokens; used by attn_local layers
    attn_logit_softcap: float = 0.0
    pos_embedding: str = "rope"       # rope | absolute

    # ffn
    mlp_act: str = "silu"             # silu (SwiGLU) | geglu | gelu (plain)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    # dispatch groups (GShard-style): 1 = global dispatch; set to the
    # data-parallel shard count so routing/scatter stays shard-local and
    # only the expert einsum crosses devices (all-to-all, not all-gather)
    moe_groups: int = 1

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # layer pattern: one period of sub-layers; model = pattern tiled over
    # num_layers (remainder layers reuse the pattern prefix)
    layer_pattern: Tuple[LayerKind, ...] = (LayerKind(),)

    # encoder-decoder / frontends
    encoder_layers: int = 0
    cross_attention: bool = False
    frontend: str = "none"            # none | audio | vision
    frontend_seq: int = 0             # stub frames/patches per example

    # misc
    tie_embeddings: bool = True
    norm: str = "rms"                 # rms | layer
    norm_eps: float = 1e-6
    embed_scale: bool = False         # gemma-style sqrt(d) embedding scale
    dtype: str = "bfloat16"

    # which assigned input shapes do not apply (with reason)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    # ------------------------------------------------------------------ #
    @property
    def n_q(self) -> int:
        return self.num_heads

    @property
    def group(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder_layers(self) -> int:
        return self.num_layers - self.num_periods * self.period

    def layer_kind(self, i: int) -> LayerKind:
        return self.layer_pattern[i % self.period]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd = self.head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        gate = 2 if self.mlp_act in ("silu", "geglu") else 1
        mlp = d * ff * gate + ff * d
        moe = (d * self.num_experts
               + self.num_experts * (d * ff * gate + ff * d))
        G = 1
        mamba = (d * (2 * self.d_inner + 2 * G * self.ssm_state + self.ssm_heads)
                 + self.d_inner * d
                 + self.ssm_conv * (self.d_inner + 2 * G * self.ssm_state)
                 + 3 * self.ssm_heads + self.d_inner)
        for i in range(self.num_layers):
            k = self.layer_kind(i)
            if k.mixer in ("attn", "attn_local"):
                total += attn
            elif k.mixer == "mamba":
                total += mamba
            if k.ffn == "mlp":
                total += mlp
            elif k.ffn == "moe":
                total += moe
            total += 2 * d  # norms
        if self.cross_attention:
            total += self.num_layers * attn
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        gate = 2 if self.mlp_act in ("silu", "geglu") else 1
        per_expert = d * ff * gate + ff * d
        inactive = 0
        for i in range(self.num_layers):
            if self.layer_kind(i).ffn == "moe":
                inactive += (self.num_experts - self.num_experts_per_tok) * per_expert
        return self.param_count() - inactive
