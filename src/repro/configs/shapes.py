"""Assigned input shapes (one set shared by all LM archs)."""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}
