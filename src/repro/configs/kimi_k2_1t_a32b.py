"""kimi-k2-1t-a32b — trillion-param MoE, 384e top-8 [arXiv:2501.kimi2].

Note: the real K2 uses one dense first layer; we model all 61 layers as
MoE (noted in DESIGN.md). head_dim=128 (64 heads project 7168->8192).
"""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    num_experts=384, num_experts_per_tok=8, capacity_factor=1.25,
    layer_pattern=(LayerKind("attn", "moe"),),
    tie_embeddings=False,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "only to sub-quadratic archs"),),
)
