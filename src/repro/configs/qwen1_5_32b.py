"""qwen1.5-32b — MHA with QKV bias [hf:Qwen]."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True,
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=False,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "to sub-quadratic archs"),),
)
