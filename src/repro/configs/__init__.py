"""Config registry: ``get_config(arch_id)`` + smoke-test reductions."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import LayerKind, ModelConfig
from .shapes import SHAPES, InputShape

_MODULES = {
    "mamba2-2.7b": "mamba2_2_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-base": "whisper_base",
    "gemma-2b": "gemma_2b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma3-1b": "gemma3_1b",
    "llava-next-34b": "llava_next_34b",
    "qwen3-4b": "qwen3_4b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "qwen3-4b"]
PAPER_ARCH = "qwen3-4b"


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    n_kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    group = cfg.group if cfg.num_heads else 0
    heads = n_kv * min(group, 2) if cfg.num_heads else 0
    # keep at least two full periods + remainder behaviour
    layers = min(cfg.num_layers, 2 * cfg.period + min(cfg.remainder_layers, 1))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=max(layers, 1),
        d_model=64,
        num_heads=heads,
        num_kv_heads=n_kv,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=257,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        capacity_factor=0.0,  # no-drop: decode must match train exactly
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=8 if cfg.ssm_state else 64,
        ssm_chunk=16,
        sliding_window=32 if cfg.sliding_window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_seq=min(cfg.frontend_seq, 12),
        dtype="float32",
    )


def shape_supported(cfg: ModelConfig, shape: str) -> bool:
    return shape not in {s for s, _ in cfg.skip_shapes}


def skip_reason(cfg: ModelConfig, shape: str) -> str:
    for s, r in cfg.skip_shapes:
        if s == shape:
            return r
    return ""
