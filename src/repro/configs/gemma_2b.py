"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    mlp_act="geglu", embed_scale=True,
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=True,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "to sub-quadratic archs"),),
)
