"""qwen3-4b — the paper's default evaluation model (§7.1): 32 query
heads, 8 KV heads, head_dim 128."""
from .base import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    layer_pattern=(LayerKind("attn", "mlp"),),
    tie_embeddings=True,
    skip_shapes=(("long_500k", "pure full attention; 500k decode assigned "
                  "to sub-quadratic archs"),),
)
