"""Profile-based PAC cost estimator (paper §5.2), TPU-adapted.

The paper shows (Table 2) that PAC execution time is neither pure-IO nor
pure-compute: small tasks are launch-bound, long-thin tasks memory-bound,
fat tasks compute-bound.  It therefore profiles ``C_est(n_q, n)`` on the
target GPU and interpolates.

On TPU we keep the identical estimator interface and combine two sources:

* an **analytic roofline model** from the v5e datasheet (197 TFLOP/s bf16,
  819 GB/s HBM) plus a constant per-grid-step overhead — this is the
  default, available without hardware;
* an optional **profiled table** measured by ``profile()`` (on whatever
  backend is present — on CPU it measures the interpret-mode kernel, which
  is only useful for unit tests; on a real TPU it measures the compiled
  kernel) with bilinear interpolation in (log2 n, log2 n_q), exactly the
  paper's scheme.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
VMEM_BYTES = 64 * 2 ** 20         # ~64 MiB usable (v5e has 128 MiB CMEM-less VMEM budget split)
GRID_STEP_OVERHEAD_S = 1.0e-6     # per grid-step pipeline bubble (calibratable)
KERNEL_LAUNCH_OVERHEAD_S = 5.0e-6  # one-off per pallas_call


@dataclasses.dataclass
class HardwareSpec:
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    grid_step_overhead: float = GRID_STEP_OVERHEAD_S
    launch_overhead: float = KERNEL_LAUNCH_OVERHEAD_S


class CostModel:
    """``C_est(n_q, n)`` — estimated seconds for one PAC task.

    ``n_q`` is the number of *queries* (requests) in the task, ``n`` the KV
    length of the (possibly divided) node slice.  Head count / head dim /
    dtype are fixed per model, supplied at construction (the paper likewise
    profiles per model).
    """

    def __init__(self, n_q_heads: int, n_kv_heads: int, head_dim: int,
                 bytes_per: int = 2, page_size: int = 64,
                 hw: Optional[HardwareSpec] = None,
                 table: Optional[Dict[Tuple[int, int], float]] = None):
        self.h_q = int(n_q_heads)
        self.h_kv = int(n_kv_heads)
        self.d = int(head_dim)
        self.bytes_per = int(bytes_per)
        self.page_size = int(page_size)
        self.hw = hw or HardwareSpec()
        self._table = dict(table) if table else None
        self._grid: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        if self._table:
            self._build_grid()

    # ------------------------------------------------------------------ #
    # analytic roofline term
    # ------------------------------------------------------------------ #
    def flops(self, n_q: int, n: int) -> float:
        # QK^T and PV, over all query heads.
        return 2.0 * 2.0 * n_q * self.h_q * n * self.d

    def hbm_bytes(self, n_q: int, n: int) -> float:
        kv = 2.0 * n * self.h_kv * self.d * self.bytes_per
        q = n_q * self.h_q * self.d * self.bytes_per
        o = n_q * self.h_q * self.d * 4  # f32 partials + m/l (negligible)
        return kv + q + o

    def analytic(self, n_q: int, n: int) -> float:
        t_flop = self.flops(n_q, n) / self.hw.peak_flops
        t_mem = self.hbm_bytes(n_q, n) / self.hw.hbm_bw
        steps = max(1, -(-int(n) // self.page_size))
        return max(t_flop, t_mem) + steps * self.hw.grid_step_overhead

    # ------------------------------------------------------------------ #
    # profiled table + bilinear interpolation (paper's estimator)
    # ------------------------------------------------------------------ #
    def _build_grid(self) -> None:
        nqs = np.array(sorted({k[0] for k in self._table}), dtype=np.float64)
        ns = np.array(sorted({k[1] for k in self._table}), dtype=np.float64)
        vals = np.full((len(nqs), len(ns)), np.nan)
        for (nq, n), v in self._table.items():
            vals[np.searchsorted(nqs, nq), np.searchsorted(ns, n)] = v
        # fill holes with analytic model so interpolation is total
        for i, nq in enumerate(nqs):
            for j, n in enumerate(ns):
                if np.isnan(vals[i, j]):
                    vals[i, j] = self.analytic(int(nq), int(n))
        self._grid = (np.log2(nqs), np.log2(ns), vals)

    def _interp(self, n_q: int, n: int) -> float:
        lnq, ln, vals = self._grid
        x, y = np.log2(max(n_q, 1)), np.log2(max(n, 1))
        i = int(np.clip(np.searchsorted(lnq, x) - 1, 0, len(lnq) - 2))
        j = int(np.clip(np.searchsorted(ln, y) - 1, 0, len(ln) - 2))
        tx = 0.0 if lnq[i + 1] == lnq[i] else np.clip(
            (x - lnq[i]) / (lnq[i + 1] - lnq[i]), 0.0, 1.0)
        ty = 0.0 if ln[j + 1] == ln[j] else np.clip(
            (y - ln[j]) / (ln[j + 1] - ln[j]), 0.0, 1.0)
        v = (vals[i, j] * (1 - tx) * (1 - ty) + vals[i + 1, j] * tx * (1 - ty)
             + vals[i, j + 1] * (1 - tx) * ty + vals[i + 1, j + 1] * tx * ty)
        return float(v)

    # ------------------------------------------------------------------ #
    def __call__(self, n_q: int, n: int) -> float:
        if n <= 0 or n_q <= 0:
            return 0.0
        if self._grid is not None:
            return self._interp(n_q, n)
        return self.analytic(n_q, n)

    # ------------------------------------------------------------------ #
    # cross-device merge term (sequence-parallel POR over ICI)
    # ------------------------------------------------------------------ #
    def merge_cost(self, n_splits: int, n_q: int) -> float:
        """Estimated seconds to POR-merge ``n_splits`` sequence-parallel
        partials of ``n_q`` queries across devices.

        The butterfly merge (``kernels.por.por_allmerge``) runs
        ``ceil(log2 n_splits)`` ppermute rounds; each round moves one
        partial set — ``(o, m, l)`` is ``n_q * h_q * (d + 2)`` f32 values
        — over an ICI link and pays one launch.  The scheduler charges
        this to every sequence-split it creates, so splitting a long
        shared-prefix node across devices must beat the wire cost it
        introduces.
        """
        if n_splits <= 1 or n_q <= 0:
            return 0.0
        rounds = int(np.ceil(np.log2(n_splits)))
        wire = n_q * self.h_q * (self.d + 2) * 4  # f32 o/m/l per round
        return rounds * (wire / self.hw.ici_bw + self.hw.launch_overhead)

    # convenience for the scheduler: is a task memory- or compute-bound?
    def bound(self, n_q: int, n: int) -> str:
        t_flop = self.flops(n_q, n) / self.hw.peak_flops
        t_mem = self.hbm_bytes(n_q, n) / self.hw.hbm_bw
        return "compute" if t_flop > t_mem else "memory"


def profile(cost_model: CostModel,
            runner: Callable[[int, int], None],
            n_qs=(1, 2, 4, 8, 16, 32, 64),
            ns=(512, 1024, 2048, 4096, 8192, 16384),
            repeats: int = 3) -> CostModel:
    """Measure ``runner(n_q, n)`` wall time and return a table-backed model.

    ``runner`` must execute one PAC of the given shape and block until
    complete (e.g. ``lambda nq, n: ops.pac(...).block_until_ready()``).
    """
    table: Dict[Tuple[int, int], float] = {}
    for nq in n_qs:
        for n in ns:
            runner(nq, n)  # warmup / compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                runner(nq, n)
            table[(nq, n)] = (time.perf_counter() - t0) / repeats
    return CostModel(cost_model.h_q, cost_model.h_kv, cost_model.d,
                     cost_model.bytes_per, cost_model.page_size,
                     cost_model.hw, table)
