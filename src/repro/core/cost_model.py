"""Profile-based PAC cost estimator (paper §5.2), TPU-adapted.

The paper shows (Table 2) that PAC execution time is neither pure-IO nor
pure-compute: small tasks are launch-bound, long-thin tasks memory-bound,
fat tasks compute-bound.  It therefore profiles ``C_est(n_q, n)`` on the
target GPU and interpolates.

On TPU we keep the identical estimator interface and combine two sources:

* an **analytic roofline model** from the v5e datasheet (197 TFLOP/s bf16,
  819 GB/s HBM) plus a constant per-grid-step overhead — this is the
  default, available without hardware;
* an optional **profiled table** measured by ``profile()`` (on whatever
  backend is present — on CPU it measures the interpret-mode kernel, which
  is only useful for unit tests; on a real TPU it measures the compiled
  kernel) with bilinear interpolation in (log2 n, log2 n_q), exactly the
  paper's scheme.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

# TPU v5e hardware constants (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
VMEM_BYTES = 64 * 2 ** 20         # ~64 MiB usable (v5e has 128 MiB CMEM-less VMEM budget split)
GRID_STEP_OVERHEAD_S = 1.0e-6     # per grid-step pipeline bubble (calibratable)
KERNEL_LAUNCH_OVERHEAD_S = 5.0e-6  # one-off per pallas_call


@dataclasses.dataclass
class HardwareSpec:
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    grid_step_overhead: float = GRID_STEP_OVERHEAD_S
    launch_overhead: float = KERNEL_LAUNCH_OVERHEAD_S


class CostModel:
    """``C_est(n_q, n)`` — estimated seconds for one PAC task.

    ``n_q`` is the number of *queries* (requests) in the task, ``n`` the KV
    length of the (possibly divided) node slice.  Head count / head dim /
    dtype are fixed per model, supplied at construction (the paper likewise
    profiles per model).
    """

    def __init__(self, n_q_heads: int, n_kv_heads: int, head_dim: int,
                 bytes_per: int = 2, page_size: int = 64,
                 hw: Optional[HardwareSpec] = None,
                 table: Optional[Dict[Tuple[int, int], float]] = None):
        self.h_q = int(n_q_heads)
        self.h_kv = int(n_kv_heads)
        self.d = int(head_dim)
        self.bytes_per = int(bytes_per)
        self.page_size = int(page_size)
        self.hw = hw or HardwareSpec()
        self.calibrated = False           # set by fit(); datasheet until then
        self._table = dict(table) if table else None
        self._grid: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        if self._table:
            self._build_grid()

    # ------------------------------------------------------------------ #
    # analytic roofline term
    # ------------------------------------------------------------------ #
    def flops(self, n_q: int, n: int) -> float:
        # QK^T and PV, over all query heads.
        return 2.0 * 2.0 * n_q * self.h_q * n * self.d

    def hbm_bytes(self, n_q: int, n: int) -> float:
        kv = 2.0 * n * self.h_kv * self.d * self.bytes_per
        q = n_q * self.h_q * self.d * self.bytes_per
        o = n_q * self.h_q * self.d * 4  # f32 partials + m/l (negligible)
        return kv + q + o

    def analytic(self, n_q: int, n: int) -> float:
        t_flop = self.flops(n_q, n) / self.hw.peak_flops
        t_mem = self.hbm_bytes(n_q, n) / self.hw.hbm_bw
        steps = max(1, -(-int(n) // self.page_size))
        return max(t_flop, t_mem) + steps * self.hw.grid_step_overhead

    # ------------------------------------------------------------------ #
    # profiled table + bilinear interpolation (paper's estimator)
    # ------------------------------------------------------------------ #
    def _build_grid(self) -> None:
        nqs = np.array(sorted({k[0] for k in self._table}), dtype=np.float64)
        ns = np.array(sorted({k[1] for k in self._table}), dtype=np.float64)
        vals = np.full((len(nqs), len(ns)), np.nan)
        for (nq, n), v in self._table.items():
            vals[np.searchsorted(nqs, nq), np.searchsorted(ns, n)] = v
        # fill holes with analytic model so interpolation is total
        for i, nq in enumerate(nqs):
            for j, n in enumerate(ns):
                if np.isnan(vals[i, j]):
                    vals[i, j] = self.analytic(int(nq), int(n))
        self._grid = (np.log2(nqs), np.log2(ns), vals)

    @staticmethod
    def _axis_cell(axis: np.ndarray, x: float) -> Tuple[int, int, float]:
        """Clamped 1-D interpolation cell ``(lo, hi, t)`` on a log2 axis.

        A single-valued axis degrades to nearest (``lo == hi``, ``t = 0``)
        instead of going through ``np.clip(searchsorted - 1, 0, -1)``,
        whose min > max behaviour is undefined by numpy and only worked
        by the accident of Python's negative-index wrapping.
        """
        if len(axis) == 1:
            return 0, 0, 0.0
        lo = int(np.clip(np.searchsorted(axis, x) - 1, 0, len(axis) - 2))
        hi = lo + 1
        t = float(np.clip((x - axis[lo]) / (axis[hi] - axis[lo]), 0.0, 1.0))
        return lo, hi, t

    def _interp(self, n_q: int, n: int) -> float:
        lnq, ln, vals = self._grid
        x, y = np.log2(max(n_q, 1)), np.log2(max(n, 1))
        i, i2, tx = self._axis_cell(lnq, x)
        j, j2, ty = self._axis_cell(ln, y)
        v = (vals[i, j] * (1 - tx) * (1 - ty) + vals[i2, j] * tx * (1 - ty)
             + vals[i, j2] * (1 - tx) * ty + vals[i2, j2] * tx * ty)
        return float(v)

    # ------------------------------------------------------------------ #
    def __call__(self, n_q: int, n: int) -> float:
        if n <= 0 or n_q <= 0:
            return 0.0
        if self._grid is not None:
            return self._interp(n_q, n)
        return self.analytic(n_q, n)

    # ------------------------------------------------------------------ #
    # cross-device merge term (sequence-parallel POR over ICI)
    # ------------------------------------------------------------------ #
    def merge_cost(self, n_splits: int, n_q: int) -> float:
        """Estimated seconds to POR-merge ``n_splits`` sequence-parallel
        partials of ``n_q`` queries across devices.

        The sparse merge (``kernels.por.por_subgroup_merge``) packs the
        ``(o, m, l)`` partials of the ``n_q`` merge-needing rows into ONE
        ``(n_q, h_q, d + 2)`` f32 buffer and runs ``ceil(log2 n_splits)``
        ppermute rounds of exactly one transfer each — so the model
        charges one launch and one wire move per round, which now matches
        the kernel (the old three-ppermute butterfly paid 3 launches a
        round for the same bytes; see ``por_allmerge``).  ``n_q`` is the
        number of rows that actually cross the wire — rows whose KV is
        replicated or single-shard-local everywhere are packed out of the
        buffer and cost nothing (``n_q == 0`` skips the collective
        entirely).  The scheduler charges this ONCE per step on top of
        the slowest shard; per-subtask surcharges would double-count it.
        """
        if n_splits <= 1 or n_q <= 0:
            return 0.0
        rounds = int(np.ceil(np.log2(n_splits)))
        wire = n_q * self.h_q * (self.d + 2) * 4  # packed f32 o/m/l buffer
        return rounds * (wire / self.hw.ici_bw + self.hw.launch_overhead)

    def replicate_gain(self, n_q: int, n: int, num_shards: int) -> float:
        """Per-step seconds saved by replicating a node on every shard
        instead of sequence-splitting it across ``num_shards``.

        Replication removes the node's rows from the cross-shard merge
        (their partials are computed bitwise-identically everywhere) but
        makes every shard attend over the FULL node instead of ``1/D`` of
        it, adding ``(D-1)/D`` of the node's cost to each shard's
        makespan.  Positive gain -> replicate (short hot prefixes: the
        Hydragen observation); negative -> split (long documents: the
        parallel-read win).  Callers must still gate on free-page
        headroom — this is a time trade, not a memory one.
        """
        if num_shards <= 1:
            return 0.0
        extra = self(n_q, n) * (num_shards - 1) / num_shards
        return self.merge_cost(num_shards, n_q) - extra

    def fit(self, samples: Sequence[Dict[str, float]],
            min_samples: int = 8) -> bool:
        """Re-fit hardware coefficients from measured step timings.

        ``samples`` are per-step feature dicts — ``hbm_bytes``,
        ``grid_steps``, ``merge_bytes``, ``merge_rounds``, ``seconds`` —
        as recorded in the engine's ``step_stats``.  Solves the
        non-negative least squares ``seconds ~= hbm_bytes/bw +
        grid_steps*step_ovh + merge_bytes/ici_bw + merge_rounds*launch +
        const`` (columns without variation keep their current
        coefficient) and installs the fitted :class:`HardwareSpec`, so
        subsequent division/balancing/merge decisions use measured
        rather than datasheet costs.  Returns True when a fit was
        installed.
        """
        rows = [s for s in samples
                if s.get("seconds", 0.0) > 0.0 and s.get("hbm_bytes", 0) > 0]
        if len(rows) < min_samples:
            return False
        feats = ["hbm_bytes", "grid_steps", "merge_bytes", "merge_rounds"]
        A = np.array([[float(s.get(f, 0.0)) for f in feats] + [1.0]
                      for s in rows])
        b = np.array([float(s["seconds"]) for s in rows])
        # normalise columns so lstsq conditioning survives byte counts
        scale = np.maximum(np.abs(A).max(axis=0), 1e-30)
        coef, *_ = np.linalg.lstsq(A / scale, b, rcond=None)
        coef = np.maximum(coef / scale, 0.0)
        hw = self.hw
        # a coefficient is identifiable only when its column actually
        # spans a range — a near-constant column (decode steady state
        # varies a few percent) is collinear with the const term and
        # lstsq splits their weight arbitrarily, amplifying noise into
        # nonsense bandwidths — so require >=30% relative variation
        # before overriding the datasheet/prior value
        varies = (np.abs(A - A.mean(axis=0)).max(axis=0)
                  > 0.3 * np.maximum(np.abs(A).max(axis=0), 1e-30))
        self.hw = HardwareSpec(
            peak_flops=hw.peak_flops,
            hbm_bw=(1.0 / coef[0] if varies[0] and coef[0] > 0
                    else hw.hbm_bw),
            ici_bw=(1.0 / coef[2] if varies[2] and coef[2] > 0
                    else hw.ici_bw),
            grid_step_overhead=(float(coef[1]) if varies[1]
                                else hw.grid_step_overhead),
            launch_overhead=(float(coef[3]) if varies[3] and coef[3] > 0
                             else hw.launch_overhead))
        self.calibrated = True
        return True

    # convenience for the scheduler: is a task memory- or compute-bound?
    def bound(self, n_q: int, n: int) -> str:
        t_flop = self.flops(n_q, n) / self.hw.peak_flops
        t_mem = self.hbm_bytes(n_q, n) / self.hw.hbm_bw
        return "compute" if t_flop > t_mem else "memory"


def profile(cost_model: CostModel,
            runner: Callable[[int, int], None],
            n_qs=(1, 2, 4, 8, 16, 32, 64),
            ns=(512, 1024, 2048, 4096, 8192, 16384),
            repeats: int = 3) -> CostModel:
    """Measure ``runner(n_q, n)`` wall time and return a table-backed model.

    ``runner`` must execute one PAC of the given shape and block until
    complete (e.g. ``lambda nq, n: ops.pac(...).block_until_ready()``).
    """
    table: Dict[Tuple[int, int], float] = {}
    for nq in n_qs:
        for n in ns:
            runner(nq, n)  # warmup / compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                runner(nq, n)
            table[(nq, n)] = (time.perf_counter() - t0) / repeats
    return CostModel(cost_model.h_q, cost_model.h_kv, cost_model.d,
                     cost_model.bytes_per, cost_model.page_size,
                     cost_model.hw, table)
