"""Task division + scheduling (paper §5.1).

The optimisation problem — choose per-node division counts ``b_k[i]`` and
an assignment of subtasks to ``m`` parallel lanes minimising the makespan —
is NP-hard (parallel task scheduling, Graham 1966).  The paper's solver:

1. set ``b_q = 1`` (dividing the query dimension forfeits the shared KV
   read, the whole point of CoDec);
2. binary-search a lower bound ``cost_l`` on the makespan using the
   monotone feasibility test derived from Eq. 4;
3. cap ``b_k[i] <= ceil(C_est(n_q_i, n_i) / cost_l)`` (Eq. 5) — nodes whose
   cost is already below the average are not divided;
4. greedy (LPT) assignment of the divided subtasks to lanes.

TPU adaptation: "thread blocks" become *lanes* — parallel execution slots =
megacore halves × (optionally) devices.  The same divider additionally
enforces hardware caps: ``max_kv_per_task`` bounds the per-task page run
(VMEM working set / plan-array width) and ``max_q_per_task`` bounds the
query tile (the kernel's Q block).  A query-dimension split is used *only*
when ``n_q`` exceeds the hardware tile — the paper's b_q=1 policy is kept
for all workload-balancing decisions.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .cost_model import CostModel


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """Undivided PAC task: one KV-forest node and its query set."""
    node_id: int
    n_q: int
    n: int            # KV tokens in the node


@dataclasses.dataclass(frozen=True)
class SubTask:
    """A divided slice: queries [q_lo,q_hi) of the node × KV [kv_lo,kv_hi)."""
    node_id: int
    q_lo: int
    q_hi: int
    kv_lo: int
    kv_hi: int
    cost: float

    @property
    def n_q(self) -> int:
        return self.q_hi - self.q_lo

    @property
    def n(self) -> int:
        return self.kv_hi - self.kv_lo


@dataclasses.dataclass
class Schedule:
    subtasks: List[SubTask]
    lane_of: List[int]                # subtask -> lane
    lane_costs: List[float]
    cost_lower_bound: float

    @property
    def makespan(self) -> float:
        return max(self.lane_costs) if self.lane_costs else 0.0

    def lanes(self, num_lanes: int) -> List[List[int]]:
        out: List[List[int]] = [[] for _ in range(num_lanes)]
        for i, lane in enumerate(self.lane_of):
            out[lane].append(i)
        return out


# --------------------------------------------------------------------- #
# admission control (serving under memory pressure)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Queue policy for the engine's admit/prefill phase.

    ``prefill_chunk``: per-step prefill token budget — ``None`` admits and
    prefills whole prompts at once (the pre-pressure behaviour), an ``int``
    is a fixed chunk, ``"auto"`` derives the chunk from the cost model so
    one step's prefill work stays within ``balance_ratio`` times the
    estimated decode-attention work of the running batch (chunked prefill
    bounds time-between-tokens interference, not memory).

    ``reserve_pages``: low watermark — admission never dips the free list
    below it, keeping headroom for decode growth of the running batch.
    ``max_running``: cap on admitted (prefilling + decoding) requests.

    ``draft_reserve_pages``: extra per-running-request headroom the
    speculative engine keeps for draft-tree pages (each draft node
    occupies one page for the duration of a verify step).  Draft
    allocation itself is best-effort — the engine skips proposing
    rather than evicting to make room — so this watermark only shapes
    *admission*, keeping the pool from being packed so tight that
    speculation never gets to draft.

    ``cascade``: co-schedule waiting requests whose prompts share forest
    paths with a just-admitted request (group key = deepest shared node
    per ``tree.match_path``) so cascade prefill computes the shared span
    once for the whole group and batches the per-request suffix chunks
    into one dispatch (DESIGN.md §14).  ``max_cascade_group`` bounds the
    group (admitted head + co-admitted partners).
    """

    prefill_chunk: Optional[Union[int, str]] = None
    reserve_pages: int = 0
    max_running: Optional[int] = None
    balance_ratio: float = 4.0
    max_auto_chunk: int = 16384
    draft_reserve_pages: int = 0
    cascade: bool = False
    max_cascade_group: int = 8

    def admission_reserve(self, num_running: int) -> int:
        """Free-page watermark admission must stay above."""
        return self.reserve_pages + self.draft_reserve_pages * num_running

    def min_working_pages(self, seq_len: int, page_size: int) -> int:
        """Smallest page count that can ever make progress on a sequence.

        Whole-prompt prefill (``prefill_chunk=None``) needs the full
        sequence resident, so the working set is every page.  Chunked
        prefill only needs one chunk plus the tail page it is growing
        into — a prompt larger than the pool is still servable as long
        as each chunk fits (earlier chunks' pages are reclaimable via
        preempt-and-recompute).  Admission raises ``MemoryError`` only
        when this floor exceeds the pool; anything above it just waits.
        """
        ps = max(int(page_size), 1)
        total = -(-max(seq_len, 1) // ps)
        pc = self.prefill_chunk
        if pc is None:
            return total
        chunk = ps if pc == "auto" else int(pc)
        return min(total, -(-max(min(seq_len, chunk), 1) // ps) + 1)

    def __post_init__(self):
        pc = self.prefill_chunk
        if isinstance(pc, str) and pc != "auto":
            raise ValueError(f"prefill_chunk must be int, None or 'auto', "
                             f"got {pc!r}")
        if isinstance(pc, int) and pc < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.max_cascade_group < 2:
            raise ValueError("max_cascade_group must be >= 2 (a group is "
                             "the admitted head plus >= 1 partner)")


class AdmissionController:
    """Deadline-aware wait queue + cost-model per-step prefill budget.

    Requests without a deadline queue FCFS; a request pushed with a
    ``deadline`` (absolute engine-clock time) is ordered
    earliest-deadline-first ahead of every later-deadline and every
    deadline-less request (EDF — the down payment on the ROADMAP's
    SLO-aware scheduling item).  Ties (equal deadlines, and the whole
    no-deadline class) keep arrival order.

    Preempted requests re-enter at the *front* regardless of deadline
    (they were admitted earliest; resuming them first preserves
    completion order and bounds each request's preemption count).
    """

    def __init__(self, policy: AdmissionPolicy, cost_model: CostModel,
                 page_size: int):
        self.policy = policy
        self.cost_model = cost_model
        self.page_size = max(int(page_size), 1)
        self.queue: Deque[int] = deque()
        self.deadline: Dict[int, float] = {}
        self._arrival: Dict[int, int] = {}
        self._seq = 0

    def __len__(self) -> int:
        return len(self.queue)

    def _key(self, rid: int) -> Tuple[float, int]:
        return (self.deadline.get(rid, float("inf")),
                self._arrival.get(rid, 0))

    def push(self, rid: int, deadline: Optional[float] = None) -> None:
        self._arrival[rid] = self._seq
        self._seq += 1
        if deadline is not None:
            self.deadline[rid] = float(deadline)
        key = self._key(rid)
        idx = next((i for i, q in enumerate(self.queue)
                    if self._key(q) > key), len(self.queue))
        self.queue.insert(idx, rid)

    def requeue(self, rid: int) -> None:
        """Re-enter a preempted request at the head of the queue."""
        self.queue.appendleft(rid)

    def peek(self) -> Optional[int]:
        return self.queue[0] if self.queue else None

    def pop(self) -> int:
        rid = self.queue.popleft()
        self._arrival.pop(rid, None)
        return rid

    def remove(self, rid: int) -> None:
        try:
            self.queue.remove(rid)
        except ValueError:
            pass
        self.deadline.pop(rid, None)
        self._arrival.pop(rid, None)

    def cascade_partners(self, anchor_nodes, key_of,
                         limit: Optional[int] = None) -> List[int]:
        """Waiting rids that cascade with a just-admitted request.

        ``anchor_nodes`` is the set of forest node ids on the admitted
        request's path; ``key_of(rid)`` maps a waiting request to its
        prompt's deepest shared forest node (``tree.match_path``), or
        ``None`` when it shares nothing worth cascading.  A waiting
        request whose key lands on the anchor path shares that prefix's
        uncached compute, so prefilling it *now* — ahead of its FCFS
        turn — turns N copies of the shared span into one (cascade
        prefill, DESIGN.md §14).  Queue order is preserved among
        partners; non-sharing requests keep their position.  The caller
        admits each partner (memory probes still apply) and calls
        :meth:`remove` for the ones it takes.
        """
        out: List[int] = []
        for rid in list(self.queue):
            if limit is not None and len(out) >= limit:
                break
            if key_of(rid) in anchor_nodes:
                out.append(rid)
        return out

    def prefill_budget(self, running_ctx: Sequence[int]) -> Optional[int]:
        """Prefill token budget for one engine step (``None`` = unlimited).

        In ``"auto"`` mode the budget is the largest page-aligned chunk
        whose estimated attention cost stays within ``balance_ratio`` times
        the running batch's decode-attention cost, so admitted prompts
        cannot monopolise a step.  With nothing decoding there is nothing
        to starve and the budget is unlimited.
        """
        pc = self.policy.prefill_chunk
        if pc is None:
            return None
        if isinstance(pc, int):
            return pc
        if not running_ctx:
            return None
        decode_cost = sum(self.cost_model(1, max(c, 1)) for c in running_ctx)
        target = self.policy.balance_ratio * decode_cost
        mean_ctx = int(sum(running_ctx) / len(running_ctx))
        chunk = self.page_size
        while (chunk * 2 <= self.policy.max_auto_chunk
               and self.cost_model(chunk * 2, mean_ctx + chunk * 2)
               <= target):
            chunk *= 2
        return chunk


# --------------------------------------------------------------------- #
# division
# --------------------------------------------------------------------- #
def _even_splits(total: int, parts: int, quantum: int) -> List[Tuple[int, int]]:
    """Split [0,total) into <=parts contiguous quantum-aligned slices."""
    nquanta = -(-total // quantum)
    parts = max(1, min(parts, nquanta))
    base, extra = divmod(nquanta, parts)
    out, lo = [], 0
    for p in range(parts):
        take = (base + (1 if p < extra else 0)) * quantum
        hi = min(total, lo + take)
        out.append((lo, hi))
        lo = hi
    return [s for s in out if s[1] > s[0]]


def divide_task(task: TaskSpec, b_k: int, cost: CostModel,
                page_size: int, max_q: Optional[int] = None) -> List[SubTask]:
    q_slices = ([(0, task.n_q)] if not max_q or task.n_q <= max_q
                else _even_splits(task.n_q, -(-task.n_q // max_q), 1))
    kv_slices = _even_splits(task.n, b_k, page_size)
    out = []
    for (qlo, qhi) in q_slices:
        for (klo, khi) in kv_slices:
            out.append(SubTask(task.node_id, qlo, qhi, klo, khi,
                               cost(qhi - qlo, khi - klo)))
    return out


def naive_divide(tasks: Sequence[TaskSpec], k: int, cost: CostModel,
                 page_size: int, max_q: Optional[int] = None) -> List[SubTask]:
    """Fixed division count for every task (paper Fig. 10 baseline)."""
    out: List[SubTask] = []
    for t in tasks:
        out.extend(divide_task(t, k, cost, page_size, max_q))
    return out


# --------------------------------------------------------------------- #
# LPT scheduling
# --------------------------------------------------------------------- #
def lpt(subtasks: Sequence[SubTask], num_lanes: int) -> Tuple[List[int], List[float]]:
    order = sorted(range(len(subtasks)), key=lambda i: -subtasks[i].cost)
    lane_cost = [0.0] * num_lanes
    lane_of = [0] * len(subtasks)
    for i in order:
        lane = int(np.argmin(lane_cost))
        lane_of[i] = lane
        lane_cost[lane] += subtasks[i].cost
    return lane_of, lane_cost


# --------------------------------------------------------------------- #
# sharded scheduling: lanes become (device, megacore-half) slots
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ShardedSchedule:
    """Per-data-shard schedules + the ICI merge term of sequence splits.

    ``shards[s]`` is the lane schedule executed by data-shard ``s`` (its
    lanes are that device's megacore halves); ``seq_splits`` counts
    subtasks that were cut at a shard boundary (their partials meet in
    the cross-device POR merge); ``merge_cost`` is the estimated ICI
    cost of that merge, charged once on top of the slowest shard.
    """

    shards: List[Schedule]
    seq_splits: int
    merge_cost: float

    @property
    def makespan(self) -> float:
        local = max((s.makespan for s in self.shards), default=0.0)
        return local + self.merge_cost


def split_at_shard_boundaries(subs: Sequence[SubTask], node_pages,
                              shard_of_page, page_size: int,
                              cost: CostModel,
                              ) -> Tuple[List[List[SubTask]], int]:
    """Cut each subtask where its page run crosses a data-shard boundary.

    ``node_pages(node_id)`` returns the node's page-id list;
    ``shard_of_page(page_id)`` its owning shard.  Returns per-shard
    subtask lists plus the number of *nodes* whose KV ended up on more
    than one shard (sequence splits — their partials meet again in the
    cross-device POR merge).  Pieces carry only their LOCAL compute
    cost: the ICI merge is charged exactly once per step by the caller
    (``ShardedSchedule.merge_cost``).  The old per-piece surcharge
    double-counted the merge — every piece of every split node paid the
    full butterfly on top of the global charge, which (a) inflated the
    predicted makespan quadratically in the split count and (b) made
    LPT treat cheap split fragments as expensive, so it piled unrelated
    work onto the unsplit shards.
    """
    ps = page_size
    out: Dict[int, List[SubTask]] = {}
    node_shards: Dict[int, set] = {}
    for s in subs:
        pages = node_pages(s.node_id)
        p_lo = s.kv_lo // ps
        p_hi = -(-s.kv_hi // ps)
        runs: List[Tuple[int, int, int]] = []   # (shard, page_a, page_b)
        for pi in range(p_lo, p_hi):
            sh = shard_of_page(pages[pi])
            node_shards.setdefault(s.node_id, set()).add(sh)
            if runs and runs[-1][0] == sh:
                runs[-1] = (sh, runs[-1][1], pi + 1)
            else:
                runs.append((sh, pi, pi + 1))
        for sh, pa, pb in runs:
            lo = max(s.kv_lo, pa * ps)
            hi = min(s.kv_hi, pb * ps)
            out.setdefault(sh, []).append(
                SubTask(s.node_id, s.q_lo, s.q_hi, lo, hi,
                        cost(s.n_q, hi - lo)))
    seq_splits = sum(1 for shards in node_shards.values() if len(shards) > 1)
    shards = [out.get(sh, []) for sh in range(max(out, default=0) + 1)]
    return shards, seq_splits


def divide_and_schedule_sharded(tasks: Sequence[TaskSpec], cost: CostModel,
                                num_shards: int, lanes_per_shard: int,
                                page_size: int, node_pages, shard_of_page,
                                num_queries: int,
                                max_kv_per_task: Optional[int] = None,
                                max_q_per_task: Optional[int] = None,
                                replicated: Optional[set] = None,
                                num_merge_queries: Optional[int] = None,
                                ) -> ShardedSchedule:
    """Mesh-aware §5.1 solver: divide over ``num_shards *
    lanes_per_shard`` (device, half) slots, force shard assignment by
    page residency (cutting sequence-split subtasks at shard
    boundaries), then LPT each shard's subtasks over its own halves.

    ``replicated`` names node ids whose KV is replicated on every shard
    (``ShardedKVPool`` replica placement): their tasks are divided over
    ONE shard's lanes and the identical subtask list is prepended to
    every shard's schedule — same slot indices, same slice boundaries —
    so each shard computes those partials bitwise identically and they
    never cross the wire.  LPT sees them as local work on every shard
    (which they are: replication trades ``(D-1)/D`` extra reads for
    zero merge traffic — ``CostModel.replicate_gain``).

    The returned makespan charges the cross-device POR merge once on
    top of the slowest shard, sized by ``num_merge_queries`` — the rows
    whose KV actually spans shards (falls back to ``num_queries``).
    """
    replicated = replicated or set()
    rep_tasks = [t for t in tasks if t.node_id in replicated]
    loc_tasks = [t for t in tasks if t.node_id not in replicated]
    rep_subs: List[SubTask] = []
    if rep_tasks:
        # divide for ONE shard's lanes: every shard runs the same copy
        rep_subs = divide_and_schedule(
            rep_tasks, cost, lanes_per_shard, page_size,
            max_kv_per_task=max_kv_per_task,
            max_q_per_task=max_q_per_task).subtasks
    base = divide_and_schedule(loc_tasks, cost,
                               num_shards * lanes_per_shard,
                               page_size, max_kv_per_task=max_kv_per_task,
                               max_q_per_task=max_q_per_task)
    per_shard, seq_splits = split_at_shard_boundaries(
        base.subtasks, node_pages, shard_of_page, page_size, cost)
    per_shard += [[] for _ in range(num_shards - len(per_shard))]
    shards = []
    for subs in per_shard[:num_shards]:
        allsubs = list(rep_subs) + subs   # identical replicated prefix
        lane_of, lane_cost = lpt(allsubs, lanes_per_shard)
        shards.append(Schedule(allsubs, lane_of, lane_cost,
                               base.cost_lower_bound))
    n_merge = num_queries if num_merge_queries is None else num_merge_queries
    merge = (cost.merge_cost(num_shards, n_merge)
             if num_shards > 1 else 0.0)
    return ShardedSchedule(shards, seq_splits, merge)


# --------------------------------------------------------------------- #
# full solver
# --------------------------------------------------------------------- #
def divide_and_schedule(tasks: Sequence[TaskSpec], cost: CostModel,
                        num_lanes: int, page_size: int,
                        max_kv_per_task: Optional[int] = None,
                        max_q_per_task: Optional[int] = None,
                        refine_steps: int = 5) -> Schedule:
    """Paper §5.1 solver: bound, cap, divide, LPT; small grid refine."""
    tasks = [t for t in tasks if t.n > 0 and t.n_q > 0]
    if not tasks:
        return Schedule([], [], [0.0] * num_lanes, 0.0)

    full_costs = [cost(t.n_q, t.n) for t in tasks]

    def build(cost_l: float) -> List[SubTask]:
        subs: List[SubTask] = []
        for t, c in zip(tasks, full_costs):
            b_k = max(1, int(np.ceil(c / max(cost_l, 1e-12))))
            max_pages = -(-t.n // page_size)
            b_k = min(b_k, max_pages)
            if max_kv_per_task is not None:
                b_k = max(b_k, -(-t.n // max_kv_per_task))
            subs.extend(divide_task(t, b_k, cost, page_size, max_q_per_task))
        return subs

    # Eq. 4 lower bound: makespan >= max(avg work / lanes, single-page task)
    lo = max(max(cost(t.n_q, min(t.n, page_size)) for t in tasks),
             sum(full_costs) / num_lanes / 4)
    hi = max(full_costs)
    # binary search the smallest cost_l whose division could meet it
    for _ in range(24):
        mid = 0.5 * (lo + hi)
        subs = build(mid)
        total = sum(s.cost for s in subs)
        feasible = (total / num_lanes <= mid
                    and max(s.cost for s in subs) <= mid)
        if feasible:
            hi = mid
        else:
            lo = mid
    cost_l = hi

    # grid refine around the bound (paper: "grid search the division
    # number ... choose the optimal division")
    best: Optional[Schedule] = None
    for mult in np.geomspace(0.5, 4.0, refine_steps):
        subs = build(cost_l * float(mult))
        lane_of, lane_cost = lpt(subs, num_lanes)
        sched = Schedule(subs, lane_of, lane_cost, cost_l)
        if best is None or sched.makespan < best.makespan:
            best = sched
    return best
