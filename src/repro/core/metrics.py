"""Typed metrics primitives for the serving telemetry layer (DESIGN §13).

Three instrument kinds, deliberately minimal and allocation-free on the
hot path:

* :class:`Counter` — a monotone float/int total (``inc``);
* :class:`Gauge` — a point-in-time value (``set``);
* :class:`Histogram` — fixed upper-bound buckets with count/sum/min/max,
  supporting interpolated quantile estimates.

A :class:`MetricsRegistry` holds instruments by name with get-or-create
semantics and produces plain-dict snapshots.  Snapshots are
NON-DESTRUCTIVE: every reader owns its own previous snapshot and takes
deltas with :func:`delta` — two readers polling at different cadences
(serve.py per report interval, serve_replay per pass) can never
double-count or starve each other.  :func:`hist_quantile` estimates
quantiles from a (possibly delta'd) histogram snapshot, so per-interval
percentiles fall out of cumulative state without per-sample storage.

The serving layer's instrument catalog and the trace-span side live in
``serving/telemetry.py``; this module is engine-agnostic.
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence


def default_time_buckets(lo: float = 1e-5, hi: float = 120.0,
                         growth: float = 1.25) -> tuple:
    """Log-spaced seconds buckets covering micro-benchmarks to stalls.

    ~70 buckets at 1.25x growth: quantile interpolation error is
    bounded by one bucket's width (<= 25% relative), fine for p50/p99
    reporting and cheap enough to snapshot every step.
    """
    bounds = []
    b = lo
    while b < hi:
        bounds.append(b)
        b *= growth
    bounds.append(hi)
    return tuple(bounds)


class Counter:
    """Monotone total.  ``inc`` rejects negative increments so registry
    consumers can rely on counters never decreasing."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} cannot decrease (inc {n})")
        self.value += n

    def snapshot(self) -> Dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Point-in-time value (occupancy, queue depth, hit rate)."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Fixed-bucket histogram over ``bounds`` (inclusive upper edges,
    with an implicit +inf overflow bucket)."""

    __slots__ = ("name", "help", "bounds", "counts", "count", "sum",
                 "min", "max")
    kind = "histogram"

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None,
                 help: str = ""):
        self.name = name
        self.help = help
        bounds = tuple(bounds) if bounds is not None \
            else default_time_buckets()
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(
                f"histogram {name!r} bounds must be strictly increasing")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        # linear scan from the low end would be O(buckets); bisect keeps
        # the hot path O(log buckets)
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        return hist_quantile(self.snapshot(), q)

    def snapshot(self) -> Dict:
        return {"type": self.kind, "bounds": list(self.bounds),
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None}


def hist_quantile(snap: Dict, q: float) -> float:
    """Interpolated quantile from a histogram snapshot (or a
    :func:`delta` of two snapshots).  Returns 0.0 when empty."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    counts = snap["counts"]
    bounds = snap["bounds"]
    total = sum(counts)
    if total == 0:
        return 0.0
    target = q * total
    cum = 0
    for i, c in enumerate(counts):
        if cum + c >= target and c > 0:
            hi = bounds[i] if i < len(bounds) else bounds[-1]
            lo = bounds[i - 1] if i > 0 else 0.0
            frac = (target - cum) / c
            v = lo + (hi - lo) * frac
            # cumulative (non-delta'd) snapshots carry exact extremes;
            # clamp so e.g. p99 of one sample returns the sample
            if snap.get("min") is not None:
                v = max(v, snap["min"])
            if snap.get("max") is not None:
                v = min(v, snap["max"])
            return v
        cum += c
    return bounds[-1]


def delta(now: Dict, prev: Dict) -> Dict:
    """Per-metric difference of two registry snapshots.

    Counters/histogram tallies subtract; gauges pass through at their
    current value (a gauge has no meaningful delta); histogram min/max
    are dropped (extremes do not difference).  Metrics absent from
    ``prev`` (registered mid-flight) difference against zero.
    """
    out = {}
    for name, s in now.items():
        p = prev.get(name)
        if s["type"] == "gauge" or p is None and s["type"] != "histogram":
            out[name] = dict(s)
        elif s["type"] == "counter":
            out[name] = {"type": "counter",
                         "value": s["value"] - p["value"]}
        elif s["type"] == "histogram":
            pc = p["counts"] if p is not None else [0] * len(s["counts"])
            out[name] = {"type": "histogram", "bounds": s["bounds"],
                         "counts": [a - b for a, b in
                                    zip(s["counts"], pc)],
                         "count": s["count"]
                         - (p["count"] if p else 0),
                         "sum": s["sum"] - (p["sum"] if p else 0.0),
                         "min": None, "max": None}
    return out


class MetricsRegistry:
    """Name-keyed instrument store with get-or-create semantics."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  help: str = "") -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = Histogram(name, bounds, help)
            self._metrics[name] = m
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not histogram")
        return m

    def _get_or_create(self, cls, name, help):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict]:
        """Plain-dict state of every instrument; safe to hold across
        steps and difference later with :func:`delta`."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def to_json(self, indent: Optional[int] = None) -> str:
        snap = self.snapshot()
        for s in snap.values():        # inf min/max are not valid JSON
            for k in ("min", "max"):
                if k in s and s[k] is not None and not math.isfinite(s[k]):
                    s[k] = None
        return json.dumps(snap, indent=indent)
