"""Decode-plan compiler: forest + schedule -> static-shape kernel arrays.

This is the CPU-side module the paper implements in C++ (§6): it runs every
few decoding steps, not every step, and its output — a ``DecodePlan`` of
flat int32 arrays — drives both the Pallas PAC kernel (via scalar prefetch)
and the XLA fallback implementation.  All arrays have static shapes so the
compiled kernel/graph is reused across plan updates.

Layout produced:

* **step-major** (for the PAC kernel): the grid is ``(num_lanes, max_steps)``
  where a *step* is one KV page of one subtask.  Lanes map to parallel
  execution slots (megacore halves); the scheduler balanced them.  Per-step
  arrays give the task id, global page id, page validity/first/last flags,
  the page's base position and valid token count.
* **task-major** (for the XLA impl + the reduction): per-task page tables,
  query gather lists, query counts/positions, and flattened segment ids
  mapping each (task, q-slot) partial to its query row (or to the trash
  segment ``num_queries`` when the slot is padding).

Partial outputs are indexed ``[task, q_slot]``; one extra trash task row
absorbs lane padding flushes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .cost_model import CostModel
from .scheduler import Schedule, SubTask, TaskSpec, divide_and_schedule
from .tree import PrefixForest


@dataclasses.dataclass
class DecodePlan:
    # sizes
    num_queries: int
    num_tasks: int            # real tasks (trash row excluded)
    num_lanes: int
    max_steps: int            # steps per lane (padded)
    max_q: int                # query slots per task
    max_pages: int            # pages per task (task-major arrays)
    page_size: int

    # step-major (num_lanes, max_steps)
    step_task: np.ndarray     # task id; padding -> lane's last task or trash
    step_page: np.ndarray     # global page id into the KV pool
    step_valid: np.ndarray    # 1 if this step does real work
    step_first: np.ndarray    # 1 on a subtask's first page
    step_last: np.ndarray     # 1 on a subtask's last page
    step_pos: np.ndarray      # absolute position of the page's first token
    step_kvlen: np.ndarray    # valid tokens in this page (1..page_size)

    # task-major (num_tasks [+1 trash], ...)
    task_qnum: np.ndarray     # (T,) valid queries of the task
    task_npages: np.ndarray   # (T,)
    task_kvlen: np.ndarray    # (T,) total KV tokens of the task slice
    task_pos: np.ndarray      # (T,) absolute position of first token
    task_pages: np.ndarray    # (T, max_pages) global page ids (pad 0)
    q_gather: np.ndarray      # (T, max_q) query rows (pad 0)
    q_pos: np.ndarray         # (T, max_q) absolute position of each query

    # reduction: flattened (T * max_q) partial -> segment id (query row,
    # or num_queries for padding slots)
    seg_ids: np.ndarray

    # bookkeeping / diagnostics
    makespan: float = 0.0
    lane_costs: Optional[List[float]] = None
    subtasks: Optional[List[SubTask]] = None

    @property
    def grid_steps(self) -> int:
        return self.num_lanes * self.max_steps

    def stats(self) -> Dict[str, float]:
        valid = float(self.step_valid.sum())
        return dict(num_tasks=self.num_tasks,
                    grid_steps=self.grid_steps,
                    valid_steps=valid,
                    grid_occupancy=valid / max(self.grid_steps, 1),
                    makespan=self.makespan,
                    lane_imbalance=(max(self.lane_costs) /
                                    (sum(self.lane_costs) / len(self.lane_costs))
                                    if self.lane_costs and sum(self.lane_costs) > 0
                                    else 1.0))


def _node_queries(node, active: Optional[set]) -> List[int]:
    """Sorted request ids of a node, filtered to the active batch."""
    if active is None:
        return sorted(node.requests)
    return [r for r in sorted(node.requests) if r in active]


def tasks_from_forest(forest: PrefixForest,
                      truncate: Optional[Dict[int, int]] = None,
                      active: Optional[set] = None) -> List[TaskSpec]:
    """``truncate`` maps node id -> effective length (engine uses this to
    exclude each leaf's growing tail page from the frozen plan);
    ``active`` restricts query sets to the live batch (finished requests
    keep their KV until released but receive no more attention)."""
    out = []
    for n in forest.real_nodes():
        ln = n.length if truncate is None else truncate.get(n.id, n.length)
        nq = len(_node_queries(n, active))
        if ln > 0 and nq > 0:
            out.append(TaskSpec(n.id, nq, ln))
    return out


def plan_key(forest: PrefixForest, rows: Sequence[int]) -> tuple:
    """Hashable signature of everything a frozen plan depends on.

    A cached plan stays valid exactly while this key is unchanged; the
    engine rebuilds when it differs.  The key captures every invalidation
    source in one place:

    * **batch membership** — the ordered active row set (arrivals,
      completions, *and evictions* all change it);
    * **path structure** — the node ids along each active request's
      prefix path (radix splits from new admissions, and node deletions
      from eviction/release, change them);
    * **tail boundary** — each leaf's full-page count: the plan truncates
      the growing last page out, so it survives in-page growth and dies
      when a leaf crosses a page boundary.

    Per-step query-position advance is handled separately (the engine's
    ``_advance_qpos``), not by rebuilding.
    """
    ps = forest.block_size
    out = []
    for r in rows:
        path = forest.path(r)
        leaf = path[-1] if path else None
        tail = 0 if leaf is None else max(0, (leaf.length - 1) // ps)
        out.append((r, tuple(n.id for n in path), tail))
    return tuple(out)


def assign_dense_pages(forest: PrefixForest) -> int:
    """Lay out every node's pages consecutively in a fresh pool.

    Returns the pool size in pages.  (The serving engine instead assigns
    pages through the paged KV-cache manager; this helper is for tests and
    benchmarks that build a pool directly from a forest.)
    """
    ps = forest.block_size
    next_page = 0
    for node in forest.real_nodes():
        npages = -(-node.length // ps)
        node.page_ids = list(range(next_page, next_page + npages))
        next_page += npages
    return max(next_page, 1)


def build_plan(forest: PrefixForest,
               cost_model: CostModel,
               num_lanes: int = 2,
               max_q: int = 64,
               max_kv_per_task: Optional[int] = 4096,
               schedule: Optional[Schedule] = None,
               req_rows: Optional[Dict[int, int]] = None,
               window: int = 0,
               truncate: Optional[Dict[int, int]] = None) -> DecodePlan:
    """Compile a forest into a DecodePlan.

    ``req_rows`` maps request id -> row in the stacked query tensor
    (defaults to sorted request-id order).  ``window``>0 drops KV pages
    wholly invisible to every query of a task under a sliding window (the
    in-kernel mask handles the page-boundary remainder).
    """
    ps = forest.block_size
    if req_rows is None:
        req_rows = {r: i for i, r in enumerate(forest.request_ids)}
    active = set(req_rows)
    nq_total = len(req_rows)

    tasks = tasks_from_forest(forest, truncate, active)
    if schedule is None:
        schedule = divide_and_schedule(
            tasks, cost_model, num_lanes, ps,
            max_kv_per_task=max_kv_per_task, max_q_per_task=max_q)
    subs = schedule.subtasks
    node_by_id = {n.id: n for n in forest.real_nodes()}

    # --- optional sliding-window pruning -------------------------------
    if window > 0:
        kept: List[SubTask] = []
        for s in subs:
            node = node_by_id[s.node_id]
            qs = _node_queries(node, active)[s.q_lo:s.q_hi]
            # a kv position p is visible to query at pos qp iff p > qp-window
            max_qpos = max(forest.context_len(r) - 1 for r in qs)
            lo_vis = max_qpos - window + 1
            task_lo = node.start_pos + s.kv_lo
            task_hi = node.start_pos + s.kv_hi
            if task_hi <= lo_vis:
                continue  # entirely out of every query's window
            new_lo = max(task_lo, (lo_vis // ps) * ps)  # page-aligned clamp
            kept.append(SubTask(s.node_id, s.q_lo, s.q_hi,
                                new_lo - node.start_pos,
                                s.kv_hi, s.cost))
        subs = kept
        lane_of, _ = _relane(subs, schedule, num_lanes)
    else:
        lane_of = schedule.lane_of

    num_tasks = len(subs)
    trash = num_tasks  # extra row for padding flushes

    # --- task-major arrays ---------------------------------------------
    max_pages = 1
    per_task_pages: List[List[int]] = []
    for s in subs:
        node = node_by_id[s.node_id]
        p_lo = s.kv_lo // ps
        p_hi = -(-s.kv_hi // ps)
        pages = node.page_ids[p_lo:p_hi]
        assert len(pages) == p_hi - p_lo, (
            f"node {s.node_id} pages not materialised")
        per_task_pages.append(pages)
        max_pages = max(max_pages, len(pages))

    T = num_tasks + 1
    task_qnum = np.zeros(T, np.int32)
    task_npages = np.zeros(T, np.int32)
    task_kvlen = np.zeros(T, np.int32)
    task_pos = np.zeros(T, np.int32)
    task_pages = np.zeros((T, max_pages), np.int32)
    q_gather = np.zeros((T, max_q), np.int32)
    q_pos = np.zeros((T, max_q), np.int32)
    seg_ids = np.full(T * max_q, nq_total, np.int32)

    for t, s in enumerate(subs):
        node = node_by_id[s.node_id]
        qs = _node_queries(node, active)[s.q_lo:s.q_hi]
        rows = [req_rows[r] for r in qs]
        nq = len(rows)
        assert nq <= max_q
        task_qnum[t] = nq
        task_npages[t] = len(per_task_pages[t])
        task_kvlen[t] = s.kv_hi - s.kv_lo
        task_pos[t] = node.start_pos + s.kv_lo
        task_pages[t, :len(per_task_pages[t])] = per_task_pages[t]
        q_gather[t, :nq] = rows
        # position index of the request's newest token (cache already
        # contains it): mask `pos <= q_pos` admits the whole cached path
        q_pos[t, :nq] = [forest.context_len(r) - 1 for r in qs]
        seg_ids[t * max_q: t * max_q + nq] = rows

    # --- step-major arrays ----------------------------------------------
    lanes: List[List[int]] = [[] for _ in range(num_lanes)]
    for i, lane in enumerate(lane_of):
        lanes[lane].append(i)
    lane_steps = [sum(len(per_task_pages[t]) for t in lane) for lane in lanes]
    S = max(max(lane_steps), 1) if lane_steps else 1

    step_task = np.full((num_lanes, S), trash, np.int32)
    step_page = np.zeros((num_lanes, S), np.int32)
    step_valid = np.zeros((num_lanes, S), np.int32)
    step_first = np.zeros((num_lanes, S), np.int32)
    step_last = np.zeros((num_lanes, S), np.int32)
    step_pos = np.zeros((num_lanes, S), np.int32)
    step_kvlen = np.ones((num_lanes, S), np.int32)

    for l, lane in enumerate(lanes):
        i = 0
        for t in lane:
            pages = per_task_pages[t]
            kv_total = int(task_kvlen[t])
            for j, pg in enumerate(pages):
                step_task[l, i] = t
                step_page[l, i] = pg
                step_valid[l, i] = 1
                step_first[l, i] = int(j == 0)
                step_last[l, i] = int(j == len(pages) - 1)
                step_pos[l, i] = int(task_pos[t]) + j * ps
                step_kvlen[l, i] = min(ps, kv_total - j * ps)
                i += 1
        # padding: repeat lane's last real task so spurious output flushes
        # rewrite already-final content (trash row if the lane is empty)
        if i > 0:
            step_task[l, i:] = step_task[l, i - 1]
            step_page[l, i:] = step_page[l, i - 1]

    return DecodePlan(
        num_queries=nq_total, num_tasks=num_tasks, num_lanes=num_lanes,
        max_steps=S, max_q=max_q, max_pages=max_pages, page_size=ps,
        step_task=step_task, step_page=step_page, step_valid=step_valid,
        step_first=step_first, step_last=step_last, step_pos=step_pos,
        step_kvlen=step_kvlen,
        task_qnum=task_qnum, task_npages=task_npages, task_kvlen=task_kvlen,
        task_pos=task_pos, task_pages=task_pages,
        q_gather=q_gather, q_pos=q_pos, seg_ids=seg_ids,
        makespan=schedule.makespan, lane_costs=list(schedule.lane_costs),
        subtasks=list(subs))


def build_verify_plan(forest: PrefixForest,
                      cost_model: CostModel,
                      query_rows: Dict[int, int],
                      num_lanes: int = 2,
                      max_q: int = 64,
                      max_kv_per_task: Optional[int] = 4096,
                      window: int = 0,
                      kind: str = "codec") -> DecodePlan:
    """Compile a multi-query *verification* plan (speculative decoding).

    A verification step scores every branch head of every request's
    draft tree in one dispatch: each draft node carries a virtual query
    id attached to it (``PrefixForest.attach_request``), and
    ``query_rows`` maps every query id — the request's committed-tail
    base query plus one per draft node — to its row in the stacked
    query tensor.  Sibling branches share all ancestor KV, so the plan's
    shared-node tasks read the trunk once for all branch-head lanes
    (the paper's §2.5 speculative-verification workload).

    Unlike the engine's frozen decode plan, NOTHING is truncated: the
    growing tail pages and the one-token draft nodes are all covered —
    the verify dispatch writes their KV before attending, and the plan
    is rebuilt every speculative step anyway (the draft tree changes),
    so there is no frozen/tail split to preserve.  ``kind`` selects the
    planner family the backend declares (``AttentionBackend.plan_kind``):
    ``"codec"`` shares prefix tasks, ``"flash"`` clones per-query tasks.
    """
    build = flash_plan if kind == "flash" else build_plan
    return build(forest, cost_model, num_lanes, max_q, max_kv_per_task,
                 req_rows=query_rows, window=window)


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= ``n`` (at least ``floor``).

    All shape bucketing for the fused decode step routes through here so
    the number of distinct jitted shapes per dimension is O(log n).
    """
    if n <= 0:
        return floor
    return max(floor, 1 << (n - 1).bit_length())


def bucket_plan(plan: DecodePlan, num_rows: int,
                steps: Optional[int] = None, tasks: Optional[int] = None,
                pages: Optional[int] = None) -> DecodePlan:
    """Bucket every plan shape the fused (jitted) decode step sees.

    ``pad_plan`` already buckets the step axis; this additionally buckets
    the task and per-task page axes to powers of two and re-targets the
    query dimension at ``num_rows`` — the *bucketed* batch-row count the
    engine stacks its queries to — so the compiled step function is
    reused across plan rebuilds (arrivals, completions, evictions) as
    long as every bucket is unchanged.

    Padded task rows clone the trash row (``task_qnum == 0``, pages 0),
    so they are inert: every implementation masks dead query slots and
    the segment reduction drops anything mapped to the trash segment.
    ``seg_ids`` entries pointing at the old trash segment
    (``plan.num_queries``) are re-pointed at ``num_rows``; real query
    rows are below the live batch size and therefore below ``num_rows``.

    Explicit ``steps``/``tasks``/``pages`` targets override the per-axis
    power-of-two defaults (the sharded planner buckets every shard to
    the common maxima so the stacked per-shard arrays stay rectangular).
    """
    if num_rows < plan.num_queries:
        raise ValueError(
            f"bucketed rows {num_rows} < live queries {plan.num_queries}")
    p = pad_plan(plan, steps=steps or bucket_pow2(plan.max_steps),
                 tasks=tasks or bucket_pow2(plan.task_qnum.shape[0]))
    pages = pages or bucket_pow2(p.max_pages)
    if pages < p.max_pages:
        raise ValueError("page bucket target smaller than plan")
    task_pages = np.zeros((p.task_qnum.shape[0], pages), np.int32)
    task_pages[:, :p.max_pages] = p.task_pages
    seg = p.seg_ids.copy()
    seg[seg == p.num_queries] = num_rows
    return dataclasses.replace(p, max_pages=pages, task_pages=task_pages,
                               seg_ids=seg, num_queries=num_rows)


def pad_plan(plan: DecodePlan, steps: Optional[int] = None,
             tasks: Optional[int] = None) -> DecodePlan:
    """Pad step/task arrays to bucketed sizes so jitted shapes are reused
    across plan rebuilds (padding steps are invalid; padded task rows are
    trash clones)."""
    S0, T0 = plan.max_steps, plan.task_qnum.shape[0]
    S = steps or 1 << (S0 - 1).bit_length()
    T = tasks or T0
    if S < S0 or T < T0:
        raise ValueError("pad target smaller than plan")

    def pad_step(a):
        return np.pad(a, ((0, 0), (0, S - S0)), mode="edge")

    def pad_task(a):
        pad = [(0, T - T0)] + [(0, 0)] * (a.ndim - 1)
        return np.pad(a, pad, mode="edge")

    step_valid = np.pad(plan.step_valid, ((0, 0), (0, S - S0)))
    step_first = np.pad(plan.step_first, ((0, 0), (0, S - S0)))
    step_last = np.pad(plan.step_last, ((0, 0), (0, S - S0)))
    seg = np.full(T * plan.max_q, plan.num_queries, np.int32)
    seg[:plan.seg_ids.shape[0]] = plan.seg_ids
    return dataclasses.replace(
        plan, max_steps=S,
        step_task=pad_step(plan.step_task), step_page=pad_step(plan.step_page),
        step_valid=step_valid, step_first=step_first, step_last=step_last,
        step_pos=pad_step(plan.step_pos), step_kvlen=pad_step(plan.step_kvlen),
        task_qnum=pad_task(plan.task_qnum),
        task_npages=pad_task(plan.task_npages),
        task_kvlen=pad_task(plan.task_kvlen),
        task_pos=pad_task(plan.task_pos),
        task_pages=pad_task(plan.task_pages),
        q_gather=pad_task(plan.q_gather), q_pos=pad_task(plan.q_pos),
        seg_ids=seg)


# --------------------------------------------------------------------- #
# mesh-aware plan partitioning (distributed serving)
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ShardedPlan:
    """One ``DecodePlan`` per data-shard, bucketed to COMMON shapes.

    Every shard's arrays share the same (steps, tasks, pages, rows)
    buckets so the engine can ``np.stack`` the per-shard prepared arrays
    into ``(D, ...)`` device inputs sharded over the mesh's ``data``
    axis; page ids inside each shard's arrays are *local* (row ids
    within that shard's pool block, including its trash row).  Queries
    are replicated: per-shard ``seg_ids`` still target global query
    rows, and the cross-device POR merge folds the per-shard partials.
    """

    shards: List[DecodePlan]
    num_shards: int
    makespan: float            # slowest shard + ICI merge term
    merge_cost: float          # cross-device POR merge estimate (s)
    seq_splits: int            # subtasks cut at a shard boundary
    # sparse-merge ownership (rows are the bucketed query rows):
    # merge_rows[r] — True iff row r's partials differ across shards and
    # must cross the wire; row_shards[s, r] — True iff shard s computes a
    # shard-local (non-replicated) contribution to row r.  The engine ORs
    # tail ownership into row_shards and derives the packed gather /
    # scatter indices + the contributor vector from these.
    merge_rows: Optional[np.ndarray] = None
    row_shards: Optional[np.ndarray] = None
    replicated: Optional[set] = None   # node ids planned from replicas

    def stats(self) -> Dict[str, float]:
        local = [p.makespan for p in self.shards]
        occ = [p.stats()["grid_occupancy"] for p in self.shards]
        return dict(num_shards=self.num_shards, makespan=self.makespan,
                    merge_cost=self.merge_cost, seq_splits=self.seq_splits,
                    shard_makespans=local,
                    shard_imbalance=(max(local) / (sum(local) / len(local))
                                     if local and sum(local) > 0 else 1.0),
                    mean_grid_occupancy=sum(occ) / max(len(occ), 1),
                    replicated_nodes=len(self.replicated or ()),
                    merge_row_count=(int(self.merge_rows.sum())
                                     if self.merge_rows is not None else 0))


def replicated_node_set(forest: PrefixForest, num_shards: int,
                        req_rows: Dict[int, int]) -> tuple:
    """Nodes plannable from replicas + per-request full-replication flag.

    A node is a replication *candidate* when the engine stored a complete
    replica set (``node.meta["replicas"]`` with one page list per shard).
    But a candidate is only *usable* if every query row it serves has its
    ENTIRE path (and hence its leaf tail) replicated: a row with any
    shard-local contribution must POR-merge across shards, and that merge
    would double-count a contribution computed identically on every
    shard (LSE-merging X with itself is not X).  So we run a fixpoint —
    drop candidates serving a not-fully-replicated row, recompute row
    flags, repeat — and plan the dropped candidates from their primary
    pages like ordinary nodes (their replicas stay resident for later
    epochs).  Returns ``(node_ids, {request_id: fully_replicated})``.
    """
    active = set(req_rows)
    R = {n.id for n in forest.real_nodes()
         if len(n.meta.get("replicas", {})) == num_shards}
    full: Dict[int, bool] = {r: False for r in active}
    if not R:
        return set(), full
    while True:
        for r in active:
            path = forest.path(r)
            full[r] = bool(path) and all(n.id in R for n in path)
        r2 = set()
        for n in forest.real_nodes():
            if n.id in R:
                qs = _node_queries(n, active)
                if qs and all(full[q] for q in qs):
                    r2.add(n.id)
        if r2 == R:
            return R, full
        R = r2


def build_sharded_plan(forest: PrefixForest,
                       cost_model: CostModel,
                       num_shards: int,
                       page_stride: int,
                       num_lanes: int = 2,
                       max_q: int = 64,
                       max_kv_per_task: Optional[int] = 4096,
                       req_rows: Optional[Dict[int, int]] = None,
                       window: int = 0,
                       truncate: Optional[Dict[int, int]] = None,
                       num_rows: Optional[int] = None) -> ShardedPlan:
    """Compile a forest into per-data-shard DecodePlans for SPMD decode.

    ``page_stride`` is the per-shard pool block size in page rows
    (``pages_per_shard + 1`` — the last row of each block is that
    shard's trash page): global page row ``g`` lives on shard
    ``g // page_stride`` as local row ``g % page_stride``.  Division
    happens over ``num_shards * num_lanes`` (device, half) slots;
    subtasks are cut at shard boundaries (a *sequence split* of the
    node — its partials meet again in the cross-device POR merge, whose
    ICI cost the scheduler charges); each shard's subtasks are then
    LPT-balanced over its own ``num_lanes`` halves and compiled with
    the standard single-device machinery.

    Nodes the engine *replicated* (``node.meta["replicas"]`` holding a
    complete per-shard page list, see ``replicated_node_set``) are
    planned once and prepended identically to every shard's schedule;
    each shard's page arrays are remapped to its own replica rows.
    Rows whose whole path is replicated are computed bitwise
    identically everywhere and excluded from the merge; the rest are
    exposed via ``merge_rows`` / ``row_shards`` for the sparse subgroup
    merge, and the merge term is sized by the merge-row count instead
    of the whole batch.
    """
    from .scheduler import divide_and_schedule_sharded

    if req_rows is None:
        req_rows = {r: i for i, r in enumerate(forest.request_ids)}
    active = set(req_rows)
    tasks = tasks_from_forest(forest, truncate, active)
    node_by_id = {n.id: n for n in forest.real_nodes()}
    rows = num_rows if num_rows is not None else len(req_rows)

    rep_nodes, full_rep = replicated_node_set(forest, num_shards, req_rows)
    merge_mask = np.zeros(max(rows, 1), dtype=bool)
    for rid, row in req_rows.items():
        if row < rows and not full_rep.get(rid, False):
            merge_mask[row] = True

    sched = divide_and_schedule_sharded(
        tasks, cost_model, num_shards, num_lanes, forest.block_size,
        node_pages=lambda nid: node_by_id[nid].page_ids,
        shard_of_page=lambda g: g // page_stride,
        num_queries=len(req_rows),
        max_kv_per_task=max_kv_per_task, max_q_per_task=max_q,
        replicated=rep_nodes,
        num_merge_queries=int(merge_mask.sum()))

    # shard-local contributors per row (tail owners are ORed in by the
    # engine).  Over-approximation is safe — a listed shard that ends up
    # contributing identity partials still merges correctly.
    row_shards = np.zeros((num_shards, max(rows, 1)), dtype=bool)
    for s, sh in enumerate(sched.shards):
        for sub in sh.subtasks:
            if sub.node_id in rep_nodes:
                continue
            node = node_by_id[sub.node_id]
            for rid in _node_queries(node, active)[sub.q_lo:sub.q_hi]:
                row = req_rows[rid]
                if row < rows:
                    row_shards[s, row] = True

    shards = [build_plan(forest, cost_model, num_lanes, max_q,
                         max_kv_per_task, schedule=s, req_rows=req_rows,
                         window=window, truncate=truncate)
              for s in sched.shards]

    # per-shard page localization: global row -> that shard's local row.
    # Default is g % page_stride; rows of replicated nodes instead map to
    # the shard's OWN replica rows (node.page_ids holds the primary's).
    remaps = []
    for s in range(num_shards):
        remap = (np.arange(num_shards * page_stride, dtype=np.int32)
                 % page_stride)
        for nid in rep_nodes:
            node = node_by_id[nid]
            rep = node.meta["replicas"][s]
            remap[np.asarray(node.page_ids, dtype=np.int64)] = (
                np.asarray(rep, dtype=np.int32) % page_stride)
        remaps.append(remap)

    # common buckets so stacked (D, ...) arrays stay rectangular
    steps_t = bucket_pow2(max(p.max_steps for p in shards))
    tasks_t = bucket_pow2(max(p.task_qnum.shape[0] for p in shards))
    pages_t = bucket_pow2(max(p.max_pages for p in shards))
    out = []
    for s, p in enumerate(shards):
        p = bucket_plan(p, rows, steps=steps_t, tasks=tasks_t,
                        pages=pages_t)
        # Padding/foreign entries fold into [0, stride) too — they are
        # masked (step_valid = 0 / kvlen bounds) everywhere, so reading a
        # wrong-but-resident local page is harmless.
        p.step_page = remaps[s][p.step_page]
        p.task_pages = remaps[s][p.task_pages]
        out.append(p)
    return ShardedPlan(out, num_shards, sched.makespan, sched.merge_cost,
                       sched.seq_splits, merge_rows=merge_mask,
                       row_shards=row_shards, replicated=rep_nodes)


def _relane(subs: Sequence[SubTask], schedule: Schedule, num_lanes: int):
    """Re-run LPT after window pruning changed the subtask list."""
    from .scheduler import lpt
    return lpt(subs, num_lanes)


def flash_plan(forest: PrefixForest, cost_model: CostModel,
               num_lanes: int = 2, max_q: int = 64,
               max_kv_per_task: Optional[int] = 4096,
               **kw) -> DecodePlan:
    """FlashDecoding-equivalent plan: NO prefix combining.

    Every request is planned as its own chain of per-node slices (each task
    has n_q = 1), i.e. the shared prefix KV is read once per request — the
    baseline CoDec is compared against.  Division/scheduling still applies
    (FlashDecoding also splits the KV dimension).
    """
    fake_subs: List[SubTask] = []
    truncate = kw.get("truncate")
    req_rows = kw.get("req_rows")
    active = set(req_rows) if req_rows is not None else None
    # Build per-(request, node) single-query tasks by cloning query slices.
    for node in forest.real_nodes():
        ln = node.length if truncate is None else truncate.get(node.id,
                                                               node.length)
        if ln <= 0:
            continue
        for qi in range(len(_node_queries(node, active))):
            fake_subs.append(SubTask(node.id, qi, qi + 1, 0, ln,
                                     cost_model(1, ln)))
    sched = _schedule_fixed_qslices(fake_subs, cost_model, num_lanes,
                                    forest.block_size, max_kv_per_task)
    return build_plan(forest, cost_model, num_lanes, max_q,
                      max_kv_per_task, schedule=sched, **kw)


def _schedule_fixed_qslices(subs: List[SubTask], cost: CostModel,
                            num_lanes: int, page_size: int,
                            max_kv: Optional[int]) -> Schedule:
    from .scheduler import _even_splits, lpt
    out: List[SubTask] = []
    for s in subs:
        if max_kv is not None and s.n > max_kv:
            for (lo, hi) in _even_splits(s.n, -(-s.n // max_kv), page_size):
                out.append(SubTask(s.node_id, s.q_lo, s.q_hi, lo, hi,
                                   cost(s.n_q, hi - lo)))
        else:
            out.append(s)
    lane_of, lane_cost = lpt(out, num_lanes)
    return Schedule(out, lane_of, lane_cost, 0.0)
