"""KV-cache prefix forest (paper §4.1).

The decode batch's KV cache is organised as a forest of nodes. Each node
holds a chunk of tokens shared by the set of requests whose prefix path
passes through it. A virtual root (id 0, length 0) connects unrelated
prefixes so a single plan covers the whole batch — including the fully
non-shared case (every request a direct child of the root).

Sharing granularity is ``block_size`` tokens (one KV page): like vLLM /
SGLang radix caches, only whole pages are shared; a partial trailing page
is always private to its leaf. Radix insertion therefore operates on
page-sized token blocks and splits nodes only at page boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

ROOT_ID = 0


@dataclasses.dataclass
class Node:
    """One chunk of prefix KV cache.

    ``length`` is the token count; ``start_pos`` the absolute position of
    the first token within any request that contains this node.  ``tokens``
    is optional (synthetic workloads only carry lengths).  ``page_ids`` is
    assigned by the KV-cache manager when the node is materialised.
    """

    id: int
    parent: int
    length: int
    start_pos: int
    tokens: Optional[np.ndarray] = None
    children: List[int] = dataclasses.field(default_factory=list)
    requests: List[int] = dataclasses.field(default_factory=list)
    page_ids: List[int] = dataclasses.field(default_factory=list)
    # engine bookkeeping: filled-token count, cached SSM states, etc.
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def end_pos(self) -> int:
        return self.start_pos + self.length


class PrefixForest:
    """Forest of KV-cache nodes with query<->node index structures."""

    def __init__(self, block_size: int = 64):
        self.block_size = int(block_size)
        self.nodes: Dict[int, Node] = {ROOT_ID: Node(ROOT_ID, -1, 0, 0)}
        self._next_id = 1
        # request id -> leaf node id
        self.leaf_of: Dict[int, int] = {}
        # optional observer called as on_split(upper, lower) after a node
        # split; the engine uses it to extend per-request pin bookkeeping
        # over the new lower half
        self.on_split = None

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    def _new_node(self, parent: int, length: int, start_pos: int,
                  tokens: Optional[np.ndarray] = None) -> Node:
        node = Node(self._next_id, parent, length, start_pos, tokens)
        self._next_id += 1
        self.nodes[node.id] = node
        self.nodes[parent].children.append(node.id)
        return node

    def add_node(self, parent: int, length: int,
                 tokens: Optional[np.ndarray] = None) -> Node:
        """Public node construction: append a child under ``parent``.

        The child starts at ``parent``'s end position (forest nodes are
        contiguous along a path).  This is the supported way for callers
        outside the forest — workload builders, draft-tree growers — to
        create nodes; ``_new_node`` is internal.
        """
        if tokens is not None:
            tokens = np.asarray(tokens)
            assert len(tokens) == length, (len(tokens), length)
        return self._new_node(parent, int(length),
                              self.nodes[parent].end_pos, tokens)

    def add_draft(self, parent: int, token: int) -> Node:
        """Grow a one-token *draft* node under ``parent``.

        Draft nodes hold speculative continuations (serving/speculation):
        sibling drafts share all ancestor KV, and each draft node is one
        branch position a verification plan can query.  They are marked
        ``meta["draft"] = True`` so engine invariants (eviction, release)
        can tell them from committed nodes; remove them with
        ``prune_leaf`` once verification accepts or rejects them.
        """
        node = self.add_node(parent, 1, np.asarray([token], np.int32))
        node.meta["draft"] = True
        return node

    def detach_request(self, request_id: int) -> None:
        """Unregister a request from its path (inverse of
        ``attach_request``); nodes and pages are left in place —
        the caller decides what to prune/release."""
        nid = self.leaf_of.pop(request_id)
        while nid != ROOT_ID:
            node = self.nodes[nid]
            node.requests.remove(request_id)
            nid = node.parent

    def prune_leaf(self, node_id: int) -> List[int]:
        """Remove a childless, requestless node; returns its ``page_ids``
        so the caller can release them through the page allocator.

        The draft-tree counterpart of ``add_draft``/``add_node``:
        rollback prunes rejected draft nodes bottom-up.
        """
        node = self.nodes[node_id]
        assert not node.children, f"prune_leaf({node_id}): has children"
        assert not node.requests, f"prune_leaf({node_id}): has requests"
        self.nodes[node.parent].children.remove(node_id)
        del self.nodes[node_id]
        return node.page_ids

    def add_chain(self, request_id: int, lengths: Sequence[int],
                  parent: int = ROOT_ID) -> int:
        """Append a chain of nodes under ``parent`` and attach a request.

        Used by synthetic workload builders where only lengths matter.
        Returns the leaf node id.
        """
        cur = self.nodes[parent]
        for ln in lengths:
            cur = self._new_node(cur.id, int(ln), cur.end_pos)
        self.attach_request(request_id, cur.id)
        return cur.id

    def attach_request(self, request_id: int, leaf_id: int) -> None:
        """Register ``request_id`` as owning the path root..leaf_id."""
        self.leaf_of[request_id] = leaf_id
        nid = leaf_id
        while nid != ROOT_ID:
            node = self.nodes[nid]
            node.requests.append(request_id)
            nid = node.parent

    def _match_child(self, cur: Node, remaining: np.ndarray):
        """First child of ``cur`` sharing >= one full page with
        ``remaining`` -> (child, page-aligned match length), else None.
        The single sharing rule both insertion and pure matching follow."""
        bs = self.block_size
        for cid in cur.children:
            child = self.nodes[cid]
            if child.tokens is None or len(child.tokens) == 0:
                continue
            if child.meta.get("draft"):
                # unverified speculative tokens may be rolled back after
                # the verify step — never match new requests into them
                continue
            if child.tokens[0] != remaining[0]:
                continue
            m = (_common_prefix_len(child.tokens, remaining) // bs) * bs
            if m > 0:
                return child, m
        return None

    def insert_tokens(self, request_id: int, tokens: np.ndarray) -> int:
        """Radix-insert a token sequence, sharing page-aligned prefixes.

        Returns the leaf node id holding this request's private tail.
        """
        tokens = np.asarray(tokens)
        pos = 0
        cur = self.nodes[ROOT_ID]
        n = len(tokens)
        while pos < n:
            matched = self._match_child(cur, tokens[pos:])
            if matched is None:
                break
            child, m = matched
            if m < child.length:
                self._split(child, m)
            pos += m
            cur = self.nodes[child.id]
        # private tail (possibly empty -> still make a leaf so the request
        # has somewhere to append generated tokens)
        tail = tokens[pos:]
        leaf = self._new_node(cur.id, len(tail), cur.end_pos,
                              tail.copy() if len(tail) else np.zeros(0, tokens.dtype))
        self.attach_request(request_id, leaf.id)
        return leaf.id

    def match_len(self, tokens: np.ndarray) -> int:
        """Page-aligned length of the longest cached prefix of ``tokens``.

        Pure query (no insertion/splitting): the admission controller uses
        it to estimate how many *new* KV pages a prompt would need.
        """
        tokens = np.asarray(tokens)
        pos = 0
        cur = self.nodes[ROOT_ID]
        n = len(tokens)
        while pos < n:
            matched = self._match_child(cur, tokens[pos:])
            if matched is None:
                break
            child, m = matched
            pos += m
            if m < child.length:
                break          # insertion would split here; match stops
            cur = child
        return pos

    def match_path(self, tokens: np.ndarray) -> Tuple[int, int]:
        """``(deepest fully-matched node id, matched length)`` of a prompt.

        Pure query like :meth:`match_len` — no insertion or splitting.
        The deepest node a prompt descends through is the cascade-prefill
        group key: waiting requests whose ``match_path`` lands on a node
        of a just-admitted request's path share that prefix's compute and
        are co-scheduled so the shared span is computed once for the
        whole group (DESIGN.md §14).  A prompt matching nothing returns
        ``(ROOT_ID, 0)``.
        """
        tokens = np.asarray(tokens)
        pos = 0
        cur = self.nodes[ROOT_ID]
        n = len(tokens)
        while pos < n:
            matched = self._match_child(cur, tokens[pos:])
            if matched is None:
                break
            child, m = matched
            pos += m
            if m < child.length:
                return child.id, pos   # partial: still descends into it
            cur = child
        return cur.id, pos

    def _split(self, node: Node, at: int) -> None:
        """Split ``node`` so its first ``at`` tokens become the parent part.

        ``at`` must be page aligned.  Existing requests keep passing
        through both halves; children/pages move to the new lower half.
        """
        assert 0 < at < node.length and at % self.block_size == 0
        lower = Node(self._next_id, node.id, node.length - at,
                     node.start_pos + at)
        self._next_id += 1
        if node.tokens is not None:
            lower.tokens = node.tokens[at:].copy()
            node.tokens = node.tokens[:at].copy()
        lower.children = node.children
        for cid in lower.children:
            self.nodes[cid].parent = lower.id
        lower.requests = list(node.requests)
        pages_per = at // self.block_size
        lower.page_ids = node.page_ids[pages_per:]
        node.page_ids = node.page_ids[:pages_per]
        node.length = at
        node.children = [lower.id]
        self.nodes[lower.id] = lower
        # split engine metadata: filled counts split at the boundary; any
        # cached end-of-node SSM state belongs to the *lower* half's end
        filled = node.meta.get("filled")
        if filled is not None:
            lower.meta["filled"] = max(0, filled - at)
            node.meta["filled"] = min(filled, at)
        if "ssm" in node.meta:
            lower.meta["ssm"] = node.meta.pop("ssm")
        # pins guard the whole pinned span: a waiting request that pinned
        # this node counted *all* its pages toward its admission estimate,
        # so both halves must stay protected (and LRU recency travels too)
        for key in ("pins", "touch"):
            if key in node.meta:
                lower.meta[key] = node.meta[key]
        # fix leaf_of for requests whose leaf was the split node
        for rid, leaf in list(self.leaf_of.items()):
            if leaf == node.id:
                self.leaf_of[rid] = lower.id
        if self.on_split is not None:
            self.on_split(node, lower)

    def append_token(self, request_id: int, token: Optional[int] = None) -> None:
        """Grow the request's private leaf by one generated token."""
        leaf = self.nodes[self.leaf_of[request_id]]
        if len(leaf.requests) > 1:
            # leaf became shared (identical prompts): fork a private child
            leaf = self._new_node(leaf.id, 0, leaf.end_pos,
                                  np.zeros(0, np.int32))
            old = self.leaf_of[request_id]
            self.leaf_of[request_id] = leaf.id
            leaf.requests = [request_id]
            # request stays registered on ancestors already
            del old
        leaf.length += 1
        if leaf.tokens is not None and token is not None:
            leaf.tokens = np.append(leaf.tokens, token)

    # ------------------------------------------------------------------ #
    # queries / paths / stats
    # ------------------------------------------------------------------ #
    @property
    def request_ids(self) -> List[int]:
        return sorted(self.leaf_of)

    def real_nodes(self) -> List[Node]:
        return [n for nid, n in sorted(self.nodes.items())
                if nid != ROOT_ID and n.length > 0]

    def path(self, request_id: int) -> List[Node]:
        """Prefix path root..leaf (excluding virtual root), top-down."""
        out: List[Node] = []
        nid = self.leaf_of[request_id]
        while nid != ROOT_ID:
            node = self.nodes[nid]
            out.append(node)
            nid = node.parent
        return list(reversed(out))

    def context_len(self, request_id: int) -> int:
        return sum(n.length for n in self.path(request_id))

    def total_tokens(self) -> int:
        return sum(n.length for n in self.real_nodes())

    def total_context(self) -> int:
        return sum(self.context_len(r) for r in self.request_ids)

    def mean_sharing_degree(self) -> float:
        """n̄_q from §4.3: Σ n_i·n_q_i / Σ n_i — the predicted IO ratio."""
        num = sum(n.length * len(n.requests) for n in self.real_nodes())
        den = self.total_tokens()
        return num / max(den, 1)

    # Analytic global-memory-access counts (paper Fig. 6 metric): bytes of
    # KV read from HBM by decode attention, ignoring Q/O traffic.
    def codec_io_bytes(self, n_kv: int, head_dim: int, bytes_per: int = 2) -> int:
        return 2 * self.total_tokens() * n_kv * head_dim * bytes_per

    def flash_io_bytes(self, n_kv: int, head_dim: int, bytes_per: int = 2) -> int:
        return 2 * self.total_context() * n_kv * head_dim * bytes_per

    def validate(self) -> None:
        """Structural invariants (used by property tests)."""
        for nid, node in self.nodes.items():
            if nid == ROOT_ID:
                continue
            parent = self.nodes[node.parent]
            assert nid in parent.children
            assert node.start_pos == parent.end_pos, (
                f"node {nid} start {node.start_pos} != parent end {parent.end_pos}")
            if node.parent != ROOT_ID:
                # a shared node's requests must be the union of its subtree
                kid_reqs = set()
                for cid in node.children:
                    kid_reqs |= set(self.nodes[cid].requests)
                leaf_reqs = {r for r, l in self.leaf_of.items() if l == nid}
                assert set(node.requests) == kid_reqs | leaf_reqs
        for rid in self.request_ids:
            path = self.path(rid)
            for node in path:
                assert rid in node.requests


def _common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    m = min(len(a), len(b))
    neq = np.nonzero(a[:m] != b[:m])[0]
    return int(neq[0]) if len(neq) else m


# ---------------------------------------------------------------------- #
# synthetic workload builders (paper §7.2 workload suite)
# ---------------------------------------------------------------------- #
def two_level(num_requests: int, shared_len: int, unique_len: int,
              block_size: int = 64) -> PrefixForest:
    """Root doc shared by everyone; one private tail per request."""
    f = PrefixForest(block_size)
    shared = f.add_node(ROOT_ID, shared_len)
    for r in range(num_requests):
        leaf = f.add_node(shared.id, unique_len)
        f.attach_request(r, leaf.id)
    return f


def full_kary(depth: int, arity: int, node_len: int,
              block_size: int = 64) -> PrefixForest:
    """Full k-ary tree of uniform chunks; one request per leaf."""
    f = PrefixForest(block_size)
    frontier = [f.add_node(ROOT_ID, node_len)]
    for _ in range(depth - 1):
        nxt = []
        for node in frontier:
            for _ in range(arity):
                nxt.append(f.add_node(node.id, node_len))
        frontier = nxt
    for r, leaf in enumerate(frontier):
        f.attach_request(r, leaf.id)
    return f


def degenerate(depth: int, node_len: int, block_size: int = 64) -> PrefixForest:
    """Left-spine tree (paper's 'DT'): each level, one request leaves."""
    f = PrefixForest(block_size)
    spine = f.add_node(ROOT_ID, node_len)
    rid = 0
    for _ in range(depth - 1):
        leaf = f.add_node(spine.id, node_len)
        f.attach_request(rid, leaf.id)
        rid += 1
        spine = f.add_node(spine.id, node_len)
    f.attach_request(rid, spine.id)
    return f


def shared_ratio(num_requests: int, total_context: int, ratio: float,
                 block_size: int = 64) -> PrefixForest:
    """2-level tree where shared tokens / total tree tokens == ratio."""
    # tree tokens = S + B*U ; context per request = S + U
    # ratio = S / (S + B*U)
    b = num_requests
    s = int(round(total_context * ratio / (ratio + (1 - ratio) * 1)))
    # Solve: choose S so that S/(S+B*U)=ratio with S+U=total_context
    u = max(1, int(round((total_context * (1 - ratio))
                         / (1 - ratio + ratio * b) * b / b)))
    s = max(block_size, total_context - u)
    # adjust u from exact formula: ratio = s/(s+b*u) -> u = s(1-ratio)/(ratio*b)
    if ratio > 0:
        u = max(1, int(round(s * (1 - ratio) / (ratio * b))))
    return two_level(b, s, u, block_size)
