"""Cross-request prefix cache policy (ChunkAttention-style persistence).

With a :class:`CachePolicy` installed, the engine stops freeing a
finished request's prefix nodes: completed requests *detach* from the
:class:`~repro.core.tree.PrefixForest` but their page-backed nodes stay
resident, so the next request sharing the prefix (hot system prompt,
RAG document) skips that prefill entirely.  Residency is bounded by two
knobs:

* ``ttl_steps`` — a cached node untouched for this many engine steps is
  evicted by the per-step sweep;
* ``max_pages`` — LRU eviction keeps total cached (requestless,
  unpinned) pages at or below this cap.

Cached nodes are also the **first reclaim tier** under memory pressure:
the watermark/preemption machinery in the engine evicts LRU cache
entries before touching any live request's KV.

Recency is tracked in ``node.meta["touch"]`` (last-touch engine step),
which :meth:`PrefixForest._split` propagates to both halves so a split
cannot launder a cold node into a fresh one.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.tree import ROOT_ID, Node, PrefixForest

__all__ = ["CachePolicy", "PrefixCache"]


@dataclass(frozen=True)
class CachePolicy:
    """Retention knobs for the persistent prefix cache.

    ``ttl_steps=None`` disables time-based expiry; ``max_pages=None``
    leaves residency bounded only by pool pressure (cache entries are
    still the first reclaim tier).
    """

    ttl_steps: Optional[int] = None
    max_pages: Optional[int] = None


class PrefixCache:
    """Bookkeeping for cached prefix nodes living inside the forest.

    The cache owns no storage of its own — cached state *is* forest
    nodes plus their KV pages.  This object tracks the LRU clock,
    decides which requestless nodes are retained vs freed, and keeps
    hit/eviction statistics for ``step_stats``.
    """

    def __init__(self, forest: PrefixForest,
                 policy: Optional[CachePolicy] = None):
        self.forest = forest
        self.policy = policy or CachePolicy()
        self.clock = 0          # advanced once per engine step
        self.stats = {
            "hits": 0,            # admissions with match_len > 0
            "misses": 0,          # admissions with no cached prefix
            "hit_tokens": 0,      # prompt tokens served from cache
            "lookup_tokens": 0,   # prompt tokens looked up
            "evicted_nodes": 0,
            "evicted_pages": 0,
        }

    # ------------------------------------------------------------- #
    # clock / recency
    # ------------------------------------------------------------- #
    def tick(self) -> None:
        self.clock += 1

    def stamp(self, node: Node) -> None:
        """Mark ``node`` as touched at the current step (LRU recency)."""
        if node.id != ROOT_ID:
            node.meta["touch"] = self.clock

    # ------------------------------------------------------------- #
    # admission-side stats
    # ------------------------------------------------------------- #
    def record_lookup(self, matched: int, total: int) -> None:
        if matched > 0:
            self.stats["hits"] += 1
        else:
            self.stats["misses"] += 1
        self.stats["hit_tokens"] += int(matched)
        self.stats["lookup_tokens"] += int(total)

    @property
    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 0.0

    # ------------------------------------------------------------- #
    # retention / eviction decisions
    # ------------------------------------------------------------- #
    def retainable(self, node: Node) -> bool:
        """Should a requestless node be kept resident as cache?

        Only page-backed prompt/generated content is worth keeping;
        empty leaves and unverified draft tokens are not.
        """
        return (node.id != ROOT_ID
                and len(node.page_ids) > 0
                and node.tokens is not None and len(node.tokens) > 0
                and not node.meta.get("draft"))

    def _evictable(self, node: Node) -> bool:
        """Cached leaf nodes eligible for eviction right now.

        Interior cached nodes become evictable once their children go
        (eviction walks leaves upward), so LRU order is enforced among
        current leaves of the cached region.
        """
        return (node.id != ROOT_ID
                and not node.children
                and not node.requests
                and not node.meta.get("pins")
                and not node.meta.get("draft")
                and len(node.page_ids) > 0)

    def candidates(self) -> List[Node]:
        """Evictable nodes, least recently touched first."""
        out = [n for n in self.forest.real_nodes() if self._evictable(n)]
        out.sort(key=lambda n: (n.meta.get("touch", -1), n.id))
        return out

    def expired(self) -> List[Node]:
        """Evictable nodes whose TTL has lapsed (oldest first)."""
        ttl = self.policy.ttl_steps
        if ttl is None:
            return []
        return [n for n in self.candidates()
                if self.clock - n.meta.get("touch", 0) > ttl]

    def resident_pages(self) -> int:
        """Pages held only by cached (requestless, unpinned) nodes."""
        return sum(len(n.page_ids) for n in self.forest.real_nodes()
                   if not n.requests and not n.meta.get("pins")
                   and not n.meta.get("draft") and n.id != ROOT_ID)

    def over_cap(self) -> int:
        """How many pages above ``max_pages`` the cache currently sits."""
        cap = self.policy.max_pages
        if cap is None:
            return 0
        return max(0, self.resident_pages() - cap)
