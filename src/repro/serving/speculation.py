"""Tree-structured speculative decoding on the prefix forest (DESIGN §10).

The paper's §2.5 motivation beyond document QA: in speculative decoding
the verifier scores a *tree* of draft continuations, where sibling
branches share all ancestor KV — exactly the access pattern a CoDec
plan exploits.  This module holds the engine-independent pieces of the
draft-propose / tree-verify / accept-rollback loop:

* :class:`SpecConfig` — the bounded draft-tree shape;
* :class:`NGramProposer` — a deterministic self-drafting proposer
  (prompt-lookup decoding: match the sequence's own recent n-gram
  against its history and replay what followed), so speculative mode
  needs no second model;
* :class:`DraftState` — the engine's per-request bookkeeping of live
  draft nodes and their virtual query ids;
* :func:`accept_walk` — the greedy acceptance rule over a scored draft
  tree.

Draft nodes are ordinary :class:`~repro.core.tree.PrefixForest` nodes
(``PrefixForest.add_draft``), one token each, each carrying a *virtual
request id* attached at the node so ``core.plan.build_verify_plan``
gives every branch position its own query lane.  The engine
(`serving/engine.py`) owns page allocation, the verification dispatch,
KV commits, and rollback ordering.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Bounds on the per-request draft tree grown each verify step.

    ``depth``      — max tokens per draft chain (branch length);
    ``branch``     — max sibling branches forked at the committed leaf;
    ``max_nodes``  — total draft nodes per request per step (each draft
                     node occupies one KV page for the step's duration);
    ``ngram``      — longest suffix n-gram the proposer matches (it
                     falls back to shorter grams down to 1).
    """

    depth: int = 4
    branch: int = 2
    max_nodes: int = 6
    ngram: int = 3

    def __post_init__(self):
        if self.depth < 1 or self.branch < 1 or self.ngram < 1:
            raise ValueError("depth/branch/ngram must be >= 1")
        if self.max_nodes < 1:
            raise ValueError("max_nodes must be >= 1")


class NGramProposer:
    """Deterministic prompt-lookup proposer (self-drafting).

    ``propose(seq)`` matches the last ``n``-gram of ``seq`` (longest
    first, ``n = cfg.ngram .. 1``) against earlier positions, most
    recent match first, and proposes the tokens that followed each
    match as a draft chain.  Distinct first tokens become sibling
    branches (up to ``cfg.branch``); total proposed tokens are capped
    at ``cfg.max_nodes``.  Pure and deterministic: the same sequence
    always yields the same draft tree, which keeps speculative runs
    reproducible and the differential harness meaningful.
    """

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg

    def propose(self, seq: Sequence[int],
                max_tokens: int = 0) -> List[List[int]]:
        """-> draft branches (token chains), all forking at the leaf.

        ``max_tokens`` additionally caps the total (0 = no extra cap);
        the engine passes the request's remaining generation budget so
        drafts past ``max_new`` are never grown.
        """
        cfg = self.cfg
        budget = cfg.max_nodes if max_tokens <= 0 else min(
            cfg.max_nodes, max_tokens)
        n_seq = len(seq)
        if n_seq < 2 or budget < 1:
            return []
        for n in range(min(cfg.ngram, n_seq - 1), 0, -1):
            key = tuple(seq[-n:])
            branches: List[List[int]] = []
            seen_first = set()
            # scan most-recent match first (recency wins ties)
            for i in range(n_seq - n - 1, -1, -1):
                if tuple(seq[i:i + n]) != key:
                    continue
                cont = list(seq[i + n:i + n + cfg.depth])
                if not cont or cont[0] in seen_first:
                    continue
                seen_first.add(cont[0])
                branches.append(cont)
                if len(branches) >= cfg.branch:
                    break
            if branches:
                return _cap_total(branches, budget)
        return []


def _cap_total(branches: List[List[int]], budget: int) -> List[List[int]]:
    """Trim chains round-robin-free: earlier (more recent) branches keep
    their full depth first; later branches get what remains."""
    out: List[List[int]] = []
    left = budget
    for chain in branches:
        take = min(len(chain), left)
        if take <= 0:
            break
        out.append(chain[:take])
        left -= take
    return out


class DraftState:
    """Live draft bookkeeping for one request (one verify step's tree).

    ``nodes`` lists draft node ids in creation order (parents before
    children within a chain) and ``virts`` the virtual query id attached
    to each; rollback walks ``nodes`` in reverse so leaves are pruned
    before their parents.
    """

    __slots__ = ("rid", "nodes", "virts")

    def __init__(self, rid: int):
        self.rid = rid
        self.nodes: List[int] = []
        self.virts: List[int] = []


def accept_walk(forest, leaf_id: int, argmax_of: Callable[[int], int],
                room: int) -> Tuple[List[int], int]:
    """Greedy acceptance over a scored draft tree.

    ``argmax_of(node_id)`` is the model's greedy next token at that
    node's head (the committed leaf's head is the normal decode
    position).  Starting at the committed leaf: if a draft child holds
    exactly the greedy token, it is accepted and the walk descends;
    otherwise the greedy token is the correction (or, past the last
    accepted draft, the bonus) and the walk stops.  ``room`` caps the
    number of accepted tokens (the request's remaining ``max_new``
    budget).

    Returns ``(accepted_node_ids, final_token)`` — the accepted chain
    top-down plus the token the engine carries as the next ``pending``.
    Greedy equivalence: every accepted token *is* the argmax given its
    exact prefix, so the committed stream is byte-identical to
    non-speculative greedy decode regardless of what was proposed.
    """
    accepted: List[int] = []
    cur = forest.nodes[leaf_id]
    while True:
        g = int(argmax_of(cur.id))
        nxt = None
        for cid in cur.children:
            ch = forest.nodes[cid]
            if (ch.meta.get("draft") and ch.tokens is not None
                    and len(ch.tokens) and int(ch.tokens[0]) == g):
                nxt = ch
                break
        if nxt is None or len(accepted) >= room:
            return accepted, g
        accepted.append(nxt.id)
        cur = nxt
