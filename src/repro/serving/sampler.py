"""Token samplers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample(logits: jnp.ndarray, key, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32 token ids.

    Each row draws from its own ``fold_in(key, row)`` stream, so row
    ``i``'s sample is independent of the batch row count — the fused
    decode path pads the batch to a bucket size, and padded rows must
    not perturb real rows' draws.

    ``temperature`` is a python float (a trace-time constant inside the
    fused step), so validation here costs nothing on device.
    """
    temperature = float(temperature)
    if temperature < 0.0 or not np.isfinite(temperature):
        raise ValueError(
            f"temperature must be finite and >= 0 (0 = greedy argmax), "
            f"got {temperature}")
    if temperature == 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1][:, None]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    rows = jnp.arange(logits.shape[0])
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(rows)
    return jax.vmap(
        lambda k, l: jax.random.categorical(k, l))(keys, logits
                                                   ).astype(jnp.int32)
