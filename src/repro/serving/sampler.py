"""Token samplers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jnp.ndarray, key, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1][:, None]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)
