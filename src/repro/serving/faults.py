"""Deterministic fault injection for the serving engine (DESIGN.md §12).

The engine cannot be hardened against failures that cannot be
reproduced, so every fault the serving stack is expected to survive is
modelled as a :class:`FaultSpec` that a :class:`FaultInjector` delivers
through a *narrow seam* in the engine:

* ``alloc``      — the page allocator reports exhaustion even though the
                   free list is non-empty (``_alloc_pages`` returns
                   ``None``), exercising the stall / preempt /
                   mid-step-recovery paths;
* ``dispatch``   — the decode dispatch raises a simulated
                   ``RESOURCE_EXHAUSTED`` (:class:`ResourceExhausted`),
                   exercising the degradation ladder + bounded retry;
* ``nan_logits`` — one request's logits turn NaN (eager: the row is
                   overwritten on the way to the sampler; fused: a KV
                   slot of the request's private leaf is corrupted so
                   the traced program itself produces NaNs), exercising
                   the per-row NaN guard and quarantine;
* ``callback``   — the user's ``on_token`` callback raises, exercising
                   callback isolation;
* ``stall``      — the dispatch sleeps ``payload`` seconds first,
                   emulating a slow device/shard (visible as an outlier
                   in ``step_stats['dispatch_time']``; the calibration
                   sample filter must reject it).

A :class:`FaultPlan` is an immutable schedule of specs — hand-written
in tests, parsed from a CLI string (``--inject``), or generated from a
seed (:meth:`FaultPlan.seeded`) so chaos runs are reproducible
byte-for-byte.  The injector consumes specs at most ``times`` each and
counts every firing in ``fired``; a seam that cannot apply a fault yet
(e.g. the target request is not running) puts the spec back with
:meth:`FaultInjector.requeue`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "KINDS", "FaultSpec", "FaultPlan", "FaultInjector",
    "InjectedFault", "ResourceExhausted", "EngineInvariantError",
]

# every seam the engine exposes, in a fixed order so seeded plans are
# stable across python versions
KINDS: Tuple[str, ...] = (
    "alloc", "dispatch", "nan_logits", "callback", "stall")


class InjectedFault(RuntimeError):
    """Raised by a seam to stand in for a real failure (callback bugs,
    device errors).  Carries the spec so handlers can attribute it."""

    def __init__(self, spec: "FaultSpec", msg: Optional[str] = None):
        super().__init__(msg or f"injected fault {spec.kind!r} "
                                f"(step {spec.step}, rid {spec.rid})")
        self.spec = spec


class ResourceExhausted(RuntimeError):
    """Simulated device-memory exhaustion — the stand-in for XLA's
    ``RESOURCE_EXHAUSTED`` status.  Backends/dispatch wrappers may also
    raise this directly for *recoverable* OOM conditions; the engine's
    degradation ladder catches it (docs/FAULTS.md)."""


class EngineInvariantError(RuntimeError):
    """A serving-time self-check (``DecodeEngine.check``) failed.

    ``failures`` lists every violated invariant, not just the first, so
    one chaos run diagnoses all the damage at once."""

    def __init__(self, failures: Sequence[str]):
        self.failures = list(failures)
        super().__init__(
            "engine invariants violated:\n  - " + "\n  - ".join(failures))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``step`` is the earliest engine step the fault may fire at (it fires
    at the first matching seam visit at or after it); ``rid`` targets a
    specific request (``None`` = first eligible); ``times`` lets one
    spec fire repeatedly (e.g. fail a dispatch twice so the ladder must
    walk two rungs); ``payload`` is kind-specific (stall seconds).
    """

    kind: str
    step: int
    rid: Optional[int] = None
    times: int = 1
    payload: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.times < 1:
            raise ValueError("times must be >= 1")


class FaultPlan:
    """Immutable, ordered schedule of :class:`FaultSpec`."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: Tuple[FaultSpec, ...] = tuple(
            sorted(specs, key=lambda s: (s.step, KINDS.index(s.kind),
                                         -1 if s.rid is None else s.rid)))

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.specs)!r})"

    @classmethod
    def seeded(cls, seed: int, steps: int = 48, rate: float = 0.08,
               kinds: Sequence[str] = KINDS,
               rids: Optional[Sequence[int]] = None,
               stall_s: float = 0.002) -> "FaultPlan":
        """Reproducible random schedule: each step draws each kind with
        probability ``rate``; row-targeted kinds pick a rid from
        ``rids`` (when given) so the chaos harness knows exactly which
        requests a schedule may corrupt."""
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []
        for step in range(steps):
            for kind in kinds:
                if rng.random() >= rate:
                    continue
                rid = None
                if kind in ("nan_logits", "callback") and rids:
                    rid = int(rng.choice(np.asarray(rids)))
                specs.append(FaultSpec(
                    kind, step, rid=rid,
                    payload=stall_s if kind == "stall" else 0.0))
        return cls(specs)

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse a CLI schedule.

        Grammar (comma-separated)::

            kind@step              one firing at/after ``step``
            kind@step:rid          targeted at request ``rid``
            kind@step*times        fire up to ``times`` times
            kind@step=payload      kind-specific payload (stall seconds)
            seed:SEED[:RATE]       a whole FaultPlan.seeded schedule

        e.g. ``--inject dispatch@3*2,nan_logits@5:0,stall@8=0.01``.
        """
        text = text.strip()
        if not text:
            return cls()
        if text.startswith("seed:"):
            parts = text.split(":")
            seed = int(parts[1])
            rate = float(parts[2]) if len(parts) > 2 else 0.08
            return cls.seeded(seed, rate=rate)
        specs = []
        for item in text.split(","):
            item = item.strip()
            if not item:
                continue
            payload = 0.0
            if "=" in item:
                item, pay = item.split("=", 1)
                payload = float(pay)
            times = 1
            if "*" in item:
                item, t = item.split("*", 1)
                times = int(t)
            kind, _, at = item.partition("@")
            if not at:
                raise ValueError(f"fault spec {item!r} needs kind@step")
            step, _, rid = at.partition(":")
            specs.append(FaultSpec(kind.strip(), int(step),
                                   rid=int(rid) if rid else None,
                                   times=times, payload=payload))
        return cls(specs)


class FaultInjector:
    """Consumes a :class:`FaultPlan` at the engine's seams.

    The engine calls :meth:`tick` once per step and each seam calls
    :meth:`take` at its decision point; a spec fires at the first
    eligible visit at/after its step.  All state is host-side and
    deterministic given the plan and the engine's (deterministic) seam
    visit order.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.step = 0
        # mutable remaining-firings per spec, in plan order
        self._armed: List[List] = [[s, s.times] for s in plan.specs]
        self.fired: Dict[str, int] = {k: 0 for k in KINDS}

    def tick(self, step: int) -> None:
        self.step = step

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def pending(self) -> int:
        """Firings still scheduled (chaos harness quiescence check)."""
        return sum(n for _, n in self._armed)

    def take(self, kind: str,
             rid: Optional[int] = None) -> Optional[FaultSpec]:
        """Consume one firing of the first eligible spec, else None."""
        for ent in self._armed:
            spec, left = ent
            if (spec.kind != kind or spec.step > self.step or left <= 0):
                continue
            if spec.rid is not None and rid is not None \
                    and spec.rid != rid:
                continue
            ent[1] -= 1
            if ent[1] == 0:
                self._armed.remove(ent)
            self.fired[kind] += 1
            return spec
        return None

    def requeue(self, spec: FaultSpec) -> None:
        """Put back a firing a seam could not apply yet (e.g. the target
        request is not in the running batch this step)."""
        self.fired[spec.kind] -= 1
        for ent in self._armed:
            if ent[0] is spec:
                ent[1] += 1
                return
        self._armed.append([spec, 1])
