"""Prefix-shared decode engine (the paper's vLLM-integration analogue).

Continuous-batching decode loop with CoDec as the attention backend,
organised as a small per-step state machine — **admit → prefill →
decode → evict** (DESIGN.md §6) — so the engine survives and exploits
memory pressure instead of raising ``MemoryError``:

* requests enter a FCFS **waiting queue**; admission is gated by a page
  watermark and a cost-model prefill budget (``core.scheduler.
  AdmissionController``), and long prompts are prefilled in **chunks**
  interleaved with decode steps;
* prompts are radix-inserted into a ``PrefixForest``; already-cached
  nodes are *not* recomputed (prefill prefix reuse) — only the new leaf's
  KV is computed, attending to the gathered cached prefix;
* decode attention = **frozen CoDec plan** over all full pages (rebuilt
  exactly when ``core.plan.plan_key`` changes: batch membership, path
  structure, or a leaf crossing a page boundary — the paper's "reuse a
  division plan for multiple decoding steps") POR-merged with a **tail
  attention** over each request's growing last page;
* when the paged pool runs dry the engine **preempts and recomputes**:
  the victim with the fewest generated tokens releases its non-shared
  pages, its shared prefix nodes stay pinned (``node.meta["pins"]``
  refcounts) and it re-enters the queue to be re-prefilled from the
  radix-cached prefix;
* Mamba layers (hybrid archs) keep per-request recurrent state, with
  end-of-node state caching so shared prefixes are also not recomputed
  for SSM mixers (the SSM analogue of prefix caching — see DESIGN.md §5);
* decode attention backends are resolved by NAME through
  ``kernels.registry`` (``codec-pallas`` / ``codec-xla`` / ``hydragen``
  prefix-shared, ``flash`` per-request baseline, ``ref`` oracle); the
  backend's ``prepare(plan)`` output is cached across steps and its
  ``partials`` are POR-merged with the tail-page attention — see
  DESIGN.md §2–§3 for the contract.

Under greedy decoding the token streams are independent of memory
pressure: a preempted-and-recomputed request produces exactly the same
tokens as in an unconstrained run (asserted by the differential test
harness).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LayerKind, ModelConfig
from ..core import plan as plan_mod
from ..core import tree as tree_mod
from ..core.cost_model import CostModel
from ..core.scheduler import AdmissionController, AdmissionPolicy
from ..kernels import ops, ref as ref_mod, registry as registry_mod
from ..models import layers as L
from ..models import mamba as M
from ..models import transformer as T
from . import sampler
from .kv_cache import PagedKVPool

# request lifecycle states
WAITING, PREFILL, RUNNING, DONE = "waiting", "prefill", "running", "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    generated: List[int] = dataclasses.field(default_factory=list)
    pending: Optional[int] = None      # sampled, not yet appended
    max_new: int = 16
    state: str = WAITING
    preemptions: int = 0
    computed_hwm: int = 0              # highest position this request ever computed
    pinned: List[int] = dataclasses.field(default_factory=list)
    kv_freed: bool = False             # done + KV reclaimed under pressure

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def seq(self) -> List[int]:
        """Full token sequence whose KV must be resident to decode."""
        return self.prompt + self.generated


def flat_layers(cfg: ModelConfig, params) -> List[Tuple[LayerKind, Dict]]:
    out = []
    if cfg.num_periods > 0:
        for pi in range(cfg.num_periods):
            period = jax.tree.map(lambda x: x[pi], params["blocks"])
            for i in range(cfg.period):
                out.append((cfg.layer_pattern[i], period[f"sub{i}"]))
    for i in range(cfg.remainder_layers):
        out.append((cfg.layer_pattern[i], params["rem"][i]))
    return out


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 page_size: int = 16, num_pages: int = 4096,
                 backend: str = "codec-pallas",
                 num_lanes: int = 2, max_q: int = 32,
                 max_kv_per_task: int = 2048,
                 replan_interval: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_chunk=None, reserve_pages: int = 0,
                 max_running: Optional[int] = None):
        assert cfg.encoder_layers == 0, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self._backend = registry_mod.get(backend)
        if (cfg.sliding_window and not self._backend.supports_window
                and any(k.mixer == "attn_local"
                        for k in cfg.layer_pattern)):
            raise ValueError(f"backend {backend!r} cannot serve "
                             f"sliding-window layers")
        self.page_size = page_size
        self.num_lanes = num_lanes
        self.max_q = max_q
        self.max_kv_per_task = max_kv_per_task
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.layers = flat_layers(cfg, params)
        self.attn_layer_idx = {j: a for a, j in enumerate(
            j for j, (k, _) in enumerate(self.layers)
            if k.mixer in ("attn", "attn_local"))}
        n_attn = len(self.attn_layer_idx)
        self.pool = PagedKVPool(max(n_attn, 1), num_pages, page_size,
                                max(cfg.num_kv_heads, 1),
                                max(cfg.head_dim, 1))
        self.forest = tree_mod.PrefixForest(page_size)
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.cost_model = CostModel(max(cfg.num_heads, 1),
                                    max(cfg.num_kv_heads, 1),
                                    max(cfg.head_dim, 1),
                                    page_size=page_size)
        self.policy = AdmissionPolicy(prefill_chunk=prefill_chunk,
                                      reserve_pages=reserve_pages,
                                      max_running=max_running)
        self.admission = AdmissionController(self.policy, self.cost_model,
                                             page_size)
        self._prefilling: List[int] = []   # admitted, prompt not fully prefilled
        # mamba per-request state, keyed by layer index
        self.mamba_state: Dict[int, Any] = {}
        # position the carried mamba state of a PREFILL request is valid at
        self._mamba_pos: Dict[int, int] = {}
        # plans keyed by window size (0 = full attention)
        self._plans: Dict[int, Any] = {}
        self._plan_dirty = True
        self._plan_key: Optional[tuple] = None
        self.replan_interval = replan_interval
        self._steps_since_plan = 0
        self.stats = {"steps": 0, "replans": 0, "plan_time": 0.0,
                      "decode_time": 0.0, "prefill_tokens": 0,
                      "admitted": 0, "preempted": 0, "reclaimed": 0,
                      "recompute_tokens": 0, "prefill_chunks": 0,
                      "prefill_stalls": 0}
        self.step_stats: List[Dict] = []

    # ------------------------------------------------------------------ #
    # request admission (admit phase) + chunked prefill (prefill phase)
    # ------------------------------------------------------------------ #
    def add_request(self, prompt: List[int], max_new: int = 16) -> int:
        """Enqueue a request; admits (and prefills) eagerly when memory
        allows, so under no pressure this behaves like immediate prefill."""
        need = -(-max(len(prompt), 1) // self.page_size)
        if need > self.pool.num_pages:
            raise MemoryError(
                f"prompt needs {need} KV pages but the pool holds only "
                f"{self.pool.num_pages}: it can never be admitted")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new=max_new)
        self.requests[rid] = req
        self.admission.push(rid)
        self._admit_phase()
        return rid

    def has_work(self) -> bool:
        return any(q.state in (WAITING, PREFILL, RUNNING)
                   for q in self.requests.values())

    def _live(self) -> List[int]:
        return [r for r in sorted(self.requests)
                if self.requests[r].state in (PREFILL, RUNNING)]

    def _active_rows(self) -> List[int]:
        return [r for r in sorted(self.requests)
                if self.requests[r].state == RUNNING]

    def _has_pages_for(self, req: Request) -> bool:
        seq = req.seq
        matched = self.forest.match_len(np.asarray(seq, np.int32))
        need = (-(-max(len(seq), 1) // self.page_size)
                - matched // self.page_size)
        return self.pool.num_free - self.policy.reserve_pages >= need

    def _admit_phase(self) -> None:
        """Admission + chunked-prefill phase.

        Continues admitted prefills first, then admits waiting requests
        FCFS within the page watermark (reclaiming finished-request KV if
        needed) and the per-step cost-model prefill budget.
        """
        running_ctx = [self.forest.context_len(r)
                       for r in self._active_rows()]
        budget = self.admission.prefill_budget(running_ctx)
        spent = 0
        # 1. advance chunked prefills already admitted
        for rid in list(self._prefilling):
            if budget is not None and spent >= budget:
                return
            req = self.requests[rid]
            if req.state != PREFILL:       # preempted by an earlier prefill
                continue
            spent += self._prefill_step(
                req, None if budget is None else budget - spent)
        # 2. admit from the queue (FCFS; head-of-line blocks)
        while len(self.admission):
            if budget is not None and spent >= budget:
                return
            if (self.policy.max_running is not None
                    and len(self._live()) >= self.policy.max_running):
                return                      # capacity cap, not memory
            head = self.requests[self.admission.peek()]
            need_total = -(-max(len(head.seq), 1) // self.page_size)
            if need_total > self.pool.num_pages:
                raise MemoryError(
                    f"request {head.rid} needs {need_total} KV pages but "
                    f"the pool holds only {self.pool.num_pages}")
            while not self._has_pages_for(head):
                if not self._reclaim_one(set(), allow_preempt=False):
                    return                  # no free memory: keep waiting
            self.admission.pop()
            self._admit(head)
            spent += self._prefill_step(
                head, None if budget is None else budget - spent)

    def _admit(self, req: Request) -> None:
        """(Re-)insert the request's sequence into the forest and release
        the pins it held while waiting (its path now keeps those nodes
        alive by membership)."""
        self.forest.insert_tokens(req.rid,
                                  np.asarray(req.seq, np.int32))
        for nid in req.pinned:
            node = self.forest.nodes.get(nid)
            if node is not None:
                node.meta["pins"] = node.meta.get("pins", 0) - 1
                self._maybe_free_node(node)
        req.pinned = []
        req.state = PREFILL
        self._prefilling.append(req.rid)
        self.stats["admitted"] += 1

    # ------------------------------------------------------------------ #
    # eviction (evict phase) / reclamation
    # ------------------------------------------------------------------ #
    def _maybe_free_node(self, node) -> None:
        """Free a node once nothing references it: no requests pass
        through it, it has no children, and no evicted request pins it."""
        if node.id == tree_mod.ROOT_ID or node.id not in self.forest.nodes:
            return
        if node.requests or node.children or node.meta.get("pins", 0) > 0:
            return
        if node.page_ids:
            self.pool.allocator.release(node.page_ids)
        parent = self.forest.nodes[node.parent]
        parent.children.remove(node.id)
        del self.forest.nodes[node.id]
        self._maybe_free_node(parent)

    def _release_kv(self, rid: int) -> None:
        """Drop a request's forest footprint (finished or released)."""
        for node in reversed(self.forest.path(rid)):
            if node.id not in self.forest.nodes:
                continue
            node.requests.remove(rid)
            self._maybe_free_node(node)
        del self.forest.leaf_of[rid]
        for st in self.mamba_state.values():
            st.pop(rid, None)
        self._mamba_pos.pop(rid, None)

    def _preempt(self, rid: int) -> None:
        """Evict a live request: release its non-shared pages, pin the
        shared prefix nodes it leaves behind, and requeue it (front) to be
        re-prefilled from the radix-cached prefix."""
        req = self.requests[rid]
        assert req.state in (PREFILL, RUNNING), req.state
        if len(req.generated) >= req.max_new:
            # generation already complete (evicted between its final append
            # and the done transition): nothing to resume, just drop the KV
            self._release_kv(rid)
            if rid in self._prefilling:
                self._prefilling.remove(rid)
            req.state = DONE
            req.kv_freed = True
            self.stats["reclaimed"] += 1
            return
        pinned = []
        for node in reversed(self.forest.path(rid)):
            if node.id not in self.forest.nodes:
                continue
            node.requests.remove(rid)
            if (node.requests or node.children
                    or node.meta.get("pins", 0) > 0):
                node.meta["pins"] = node.meta.get("pins", 0) + 1
                pinned.append(node.id)
            else:
                if node.page_ids:
                    self.pool.allocator.release(node.page_ids)
                parent = self.forest.nodes[node.parent]
                parent.children.remove(node.id)
                del self.forest.nodes[node.id]
        del self.forest.leaf_of[rid]
        for st in self.mamba_state.values():
            st.pop(rid, None)
        self._mamba_pos.pop(rid, None)
        if rid in self._prefilling:
            self._prefilling.remove(rid)
        req.pinned = pinned
        req.state = WAITING
        req.preemptions += 1
        self.admission.requeue(rid)
        self.stats["preempted"] += 1

    def _reclaimable_pages(self, rid: int) -> int:
        """Pages that preempting ``rid`` would free (its non-shared nodes)."""
        n = 0
        freeable: Set[int] = set()
        for node in reversed(self.forest.path(rid)):
            others = [r for r in node.requests if r != rid]
            kids = set(node.children) - freeable
            if others or kids or node.meta.get("pins", 0) > 0:
                continue
            freeable.add(node.id)
            n += len(node.page_ids)
        return n

    def _reclaim_one(self, exclude: Set[int],
                     allow_preempt: bool = True) -> bool:
        """Free some pages, cheapest first: (1) finished-request KV,
        (2) orphaned pinned nodes, (3) preempt the live victim with the
        fewest generated tokens (ties: latest arrival)."""
        for rid in sorted(self.requests):
            q = self.requests[rid]
            complete = (q.state == DONE
                        or (q.state == RUNNING
                            and len(q.generated) >= q.max_new))
            if (complete and not q.kv_freed and rid not in exclude
                    and rid in self.forest.leaf_of):
                self._release_kv(rid)
                q.state = DONE
                q.kv_freed = True
                self.stats["reclaimed"] += 1
                return True
        for rid in sorted(self.requests):
            q = self.requests[rid]
            if q.state != WAITING or not q.pinned:
                continue
            for nid in list(q.pinned):
                node = self.forest.nodes.get(nid)
                if node is None:
                    q.pinned.remove(nid)
                    continue
                if not node.requests and not node.children:
                    # drop this waiter's pin; the node frees once the last
                    # pin goes (multiply-pinned nodes shed one pin per
                    # holder until the final drop releases the pages)
                    q.pinned.remove(nid)
                    node.meta["pins"] = node.meta.get("pins", 0) - 1
                    self._maybe_free_node(node)
                    if nid not in self.forest.nodes:
                        self.stats["reclaimed"] += 1
                        return True
        if not allow_preempt:
            return False
        victims = [r for r in sorted(self.requests)
                   if self.requests[r].state in (PREFILL, RUNNING)
                   and r not in exclude
                   and self._reclaimable_pages(r) > 0]
        if not victims:
            return False
        victim = min(victims,
                     key=lambda r: (len(self.requests[r].generated), -r))
        self._preempt(victim)
        return True

    def _alloc_pages(self, n: int, exclude: Set[int],
                     allow_preempt: bool = True) -> Optional[List[int]]:
        """Allocate ``n`` pages, evicting under pressure; ``None`` when
        nothing more can be reclaimed (caller stalls or raises)."""
        while self.pool.num_free < n:
            if not self._reclaim_one(exclude, allow_preempt):
                return None
        return self.pool.allocator.alloc(n)

    # ------------------------------------------------------------------ #
    # prefill with prefix reuse (chunked, resumable)
    # ------------------------------------------------------------------ #
    def _ensure_pages_upto(self, rid: int, upto: int) -> bool:
        """Allocate pages covering tokens [0, upto) of the path; False when
        allocation stalls (partial allocations are kept for the retry)."""
        for node in self.forest.path(rid):
            cover = min(node.length, max(0, upto - node.start_pos))
            need = -(-cover // self.page_size)
            if len(node.page_ids) < need:
                got = self._alloc_pages(need - len(node.page_ids),
                                        exclude={rid})
                if got is None:
                    return False
                node.page_ids += got
        return True

    def _gather_prefix_upto(self, layer_attn: int, path, upto: int) -> Tuple:
        """Dense (upto, n_kv, hd) of the path's first ``upto`` cached tokens."""
        ks, vs = [], []
        pos = 0
        for node in path:
            take = min(node.length, upto - pos)
            if take <= 0:
                break
            npg = -(-take // self.page_size)
            k, v = self.pool.gather_context(layer_attn,
                                            node.page_ids[:npg], take)
            ks.append(k)
            vs.append(v)
            pos += take
        if not ks:
            hkv, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            z = jnp.zeros((0, hkv, hd), self.pool.k.dtype)
            return z, z
        return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)

    def _promote(self, req: Request) -> None:
        req.state = RUNNING
        if req.rid in self._prefilling:
            self._prefilling.remove(req.rid)
        self._mamba_pos.pop(req.rid, None)

    def _prefill_step(self, req: Request, budget: Optional[int]) -> int:
        """Advance the request's prefill by one chunk of ``<= budget``
        tokens (``None`` = the whole remaining prompt); returns tokens
        computed (0 = stalled on pages, retried next step).

        Attention KV of the cached prefix is reused (gathered from the
        paged pool); SSM layers resume from the deepest cached boundary —
        the carried chunk state, else a node-boundary ``meta["ssm"]``
        cache — and states are (re-)cached at every shared-node boundary
        inside the recomputed span so later siblings resume exactly.
        When the sequence completes, the request joins the decode batch;
        ``pending`` is sampled only if it did not survive a preemption.
        """
        cfg = self.cfg
        rid = req.rid
        seq = req.seq
        total = len(seq)
        path = self.forest.path(rid)

        # contiguous filled-KV front along the path
        kv_filled = 0
        for node in path:
            f = min(node.meta.get("filled", 0), node.length)
            kv_filled += f
            if f < node.length:
                break

        has_mamba = any(k.mixer == "mamba" for k, _ in self.layers)

        if kv_filled < total:
            attn_start = kv_filled
        elif req.pending is None:
            # fully cached prompt: recompute the last non-empty node so the
            # final-position logits exist
            last = next((n for n in reversed(path) if n.length > 0), None)
            attn_start = total - (last.length if last is not None else 0)
        else:
            attn_start = total

        mamba_init: Dict[int, Any] = {}
        mamba_start = 0
        if has_mamba:
            carried = self._mamba_pos.get(rid)
            if carried is not None and carried == attn_start:
                mamba_start = carried
                mamba_init = {j: st[rid]
                              for j, st in self.mamba_state.items()
                              if rid in st}
            else:
                pos = 0
                for node in path:
                    f = min(node.meta.get("filled", 0), node.length)
                    pos += node.length
                    if f < node.length or pos > attn_start:
                        break
                    if "ssm" in node.meta:
                        mamba_start, mamba_init = pos, node.meta["ssm"]

        if attn_start >= total and (not has_mamba or mamba_start >= total):
            self._promote(req)
            return 0

        span_start = min(attn_start, mamba_start) if has_mamba \
            else attn_start
        end = total if budget is None else min(
            total, max(span_start + max(budget, 1), kv_filled + 1))

        if not self._ensure_pages_upto(rid, end):
            self.stats["prefill_stalls"] += 1
            return 0

        tokens = np.asarray(seq[span_start:end], np.int32)
        Tn = len(tokens)
        positions = (span_start + np.arange(Tn))[None]           # (1, Tn)

        # node segments covering the span (for KV writes + state caching)
        segments = []        # (node, lo, hi) in span-local coordinates
        off = 0
        for node in path:
            lo = max(0, off - span_start)
            hi = min(end, off + node.length) - span_start
            if hi > lo:
                segments.append((node, lo, hi))
            off += node.length

        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None],
                     jnp.asarray(positions))
        leaf_id = self.forest.leaf_of[rid]

        new_kv_writes = []  # (layer_attn, k (Tn,kv,hd), v)
        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 jnp.asarray(positions))
                pk, pv = self._gather_prefix_upto(la, path, span_start)
                k_all = jnp.concatenate([pk.astype(k_new.dtype)[None],
                                         k_new], 1)
                v_all = jnp.concatenate([pv.astype(v_new.dtype)[None],
                                         v_new], 1)
                o = L.mha(q, k_all, v_all, causal=True, window=window,
                          softcap=cfg.attn_logit_softcap,
                          q_positions=jnp.asarray(positions),
                          kv_positions=jnp.arange(end)[None])
                y = L.dense(p["attn"]["wo"],
                            o.reshape(1, Tn, cfg.num_heads * cfg.head_dim))
                new_kv_writes.append((la, k_new[0], v_new[0]))
                x = x + y
            elif kind.mixer == "mamba":
                state = mamba_init.get(j)
                ys = []
                for node, lo, hi in segments:
                    y_seg, state = self._mamba_prefill(p["mamba"],
                                                       h[:, lo:hi], state)
                    ys.append(y_seg)
                    # cache end-of-node state (shared nodes only, and only
                    # when the chunk reaches the node boundary; a leaf's
                    # state keeps moving, carried per request below)
                    if (node.id != leaf_id
                            and span_start + hi == node.end_pos):
                        node.meta.setdefault("ssm", {})[j] = state
                y = jnp.concatenate(ys, 1)
                self.mamba_state.setdefault(j, {})[rid] = state
                x = x + y
            if kind.ffn != "none":
                h2 = L.apply_norm(p["ln2"], x, cfg)
                if kind.ffn == "moe":
                    y2, _ = L.apply_moe(p["ffn"], cfg, h2)
                else:
                    y2 = L.apply_mlp(p["ffn"], cfg, h2)
                x = x + y2

        # write new KV into unfilled page slots only
        offs, pages, kv_rows = [], [], []
        ps = self.page_size
        for node, lo, hi in segments:
            start = node.meta.get("filled", 0)
            base = node.start_pos - span_start   # span-local index of token 0
            t_hi = hi - base
            for t in range(max(start, lo - base), t_hi):
                pages.append(node.page_ids[t // ps])
                offs.append(t % ps)
                kv_rows.append(base + t)
            if t_hi > start:
                node.meta["filled"] = t_hi
        if kv_rows:
            rows = jnp.asarray(np.asarray(kv_rows))
            for la, k_new, v_new in new_kv_writes:
                self.pool.write_tokens(la, np.asarray(pages),
                                       np.asarray(offs),
                                       k_new[rows], v_new[rows])

        self.stats["prefill_tokens"] += Tn
        self.stats["recompute_tokens"] += max(
            0, min(end, req.computed_hwm) - span_start)
        req.computed_hwm = max(req.computed_hwm, end)

        if end < total:
            self.stats["prefill_chunks"] += 1
            if has_mamba:
                self._mamba_pos[rid] = end
            return Tn

        if req.pending is None:
            logits = T._unembed(self.params, cfg, x)[0, -1]
            self.key, sk = jax.random.split(self.key)
            req.pending = int(sampler.sample(logits[None], sk,
                                             self.temperature)[0])
        self._promote(req)
        return Tn

    def _mamba_prefill(self, p, h, init):
        cfg = self.cfg
        if init is None:
            return M.mamba_forward(p, cfg, h)
        conv0, ssm0 = init
        # run chunked SSD from a carried state
        zxbcdt = h @ p["in_proj"]["w"]
        z, xBC_raw, dt = M._split_proj(cfg, zxbcdt)
        xBC = M._causal_conv(xBC_raw, p["conv_w"], p["conv_b"],
                             init_state=conv0)
        d_in, S = cfg.d_inner, cfg.ssm_state
        B, Tn = h.shape[0], h.shape[1]
        x_ssm = xBC[..., :d_in].reshape(B, Tn, cfg.ssm_heads,
                                        cfg.ssm_head_dim)
        Bm = xBC[..., d_in:d_in + S]
        Cm = xBC[..., d_in + S:]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, final = M.ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 init_state=ssm0)
        y = y + x_ssm.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
        y = y.reshape(B, Tn, d_in)
        y = M._gated_norm(y, z, p["norm"], cfg.norm_eps)
        out = y @ p["out_proj"]["w"]
        K = cfg.ssm_conv
        conv_tail = jnp.concatenate([conv0, xBC_raw.astype(jnp.float32)],
                                    1)[:, -(K - 1):]
        return out, (conv_tail, final)

    # ------------------------------------------------------------------ #
    # plan management
    # ------------------------------------------------------------------ #
    def _windows(self) -> List[int]:
        ws = set()
        for kind, _ in self.layers:
            if kind.mixer == "attn":
                ws.add(0)
            elif kind.mixer == "attn_local":
                ws.add(self.cfg.sliding_window)
        return sorted(ws)

    @property
    def plan_rebuilds(self) -> int:
        """Rebuild counter (the plan-lifecycle tests consume this)."""
        return self.stats["replans"]

    def _rebuild_plans(self) -> None:
        t0 = time.perf_counter()
        rows = self._active_rows()
        req_rows = {r: i for i, r in enumerate(rows)}
        ps = self.page_size
        truncate = {}
        for r in rows:
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tail_start = max(0, ((leaf.length - 1) // ps) * ps)
            truncate[leaf.id] = tail_start
        build = (plan_mod.flash_plan if self._backend.plan_kind == "flash"
                 else plan_mod.build_plan)
        self._plans = {}
        for w in self._windows():
            p = build(
                self.forest, self.cost_model, self.num_lanes, self.max_q,
                self.max_kv_per_task, req_rows=req_rows, window=w,
                truncate=truncate)
            p = plan_mod.pad_plan(p)
            self._plans[w] = (p, self._backend.prepare(p))
        self._plan_key = plan_mod.plan_key(self.forest, rows)
        self._plan_dirty = False
        self._steps_since_plan = 0
        self.stats["replans"] += 1
        self.stats["plan_time"] += time.perf_counter() - t0

    def _advance_qpos(self) -> None:
        """Cheap per-step plan refresh: live queries moved one position."""
        for w, (p, _) in list(self._plans.items()):
            slot = np.arange(p.max_q)[None, :]
            live = slot < p.task_qnum[:, None]
            p.q_pos = p.q_pos + live.astype(np.int32)
            self._plans[w] = (p, self._backend.prepare(p))

    # ------------------------------------------------------------------ #
    # decode step (admit -> prefill -> decode -> evict state machine)
    # ------------------------------------------------------------------ #
    def step(self) -> Dict[int, int]:
        """One engine step: admission + chunked prefill, then append
        pending tokens (evicting under pressure) and decode one token per
        running request."""
        snap = {k: self.stats[k]
                for k in ("admitted", "preempted", "reclaimed",
                          "prefill_tokens", "recompute_tokens")}
        self._admit_phase()
        out = self._decode_phase()
        self.step_stats.append({
            "step": len(self.step_stats),
            "decoded": len(out),
            "admitted": self.stats["admitted"] - snap["admitted"],
            "preempted": self.stats["preempted"] - snap["preempted"],
            "reclaimed": self.stats["reclaimed"] - snap["reclaimed"],
            "prefill_tokens": (self.stats["prefill_tokens"]
                               - snap["prefill_tokens"]),
            "recompute_tokens": (self.stats["recompute_tokens"]
                                 - snap["recompute_tokens"]),
            "waiting": len(self.admission),
            "prefilling": len(self._prefilling),
            "running": len(self._active_rows()),
            "pages_free": self.pool.num_free,
            "occupancy": self.pool.occupancy(),
        })
        return out

    def _decode_phase(self) -> Dict[int, int]:
        cfg = self.cfg
        rows0 = self._active_rows()
        if not rows0:
            return {}
        t0 = time.perf_counter()
        # 1. append pending tokens to leaves; grow tail pages, preempting
        #    the fewest-generated victim when the pool runs dry
        for r in rows0:
            req = self.requests[r]
            if req.state != RUNNING:   # evicted growing an earlier row
                continue
            tok = req.pending
            self.forest.append_token(r, tok)
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            if -(-leaf.length // self.page_size) > len(leaf.page_ids):
                got = self._alloc_pages(1, exclude={r})
                if got is None:
                    raise MemoryError(
                        f"KV pool exhausted growing request {r}: nothing "
                        f"left to evict (pool smaller than the working set)")
                leaf.page_ids += got
            req.generated.append(tok)
            req.pending = None
        rows = self._active_rows()
        if not rows:
            return {}
        tokens = [self.requests[r].generated[-1] for r in rows]

        # 2. plan lifecycle: rebuild exactly when the plan key changed
        #    (membership, path structure, tail page) or on the interval
        if (self.replan_interval is not None
                and self._steps_since_plan >= self.replan_interval):
            self._plan_dirty = True
        if (self._plan_dirty
                or plan_mod.plan_key(self.forest, rows) != self._plan_key):
            self._rebuild_plans()
        else:
            self._advance_qpos()
        self._steps_since_plan += 1

        B = len(rows)
        ctx = np.array([self.forest.context_len(r) for r in rows], np.int32)
        q_pos = jnp.asarray(ctx - 1)
        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None].T,
                     q_pos[:, None])                       # (B,1,d)

        # tail page info
        tail_pages, tail_base, tail_off = [], [], []
        for i, r in enumerate(rows):
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tp = (leaf.length - 1) // self.page_size
            tail_pages.append(leaf.page_ids[tp])
            tail_base.append(leaf.start_pos + tp * self.page_size)
            tail_off.append((leaf.length - 1) % self.page_size)
        tail_pages = np.asarray(tail_pages)
        tail_base = jnp.asarray(np.asarray(tail_base))
        tail_off = np.asarray(tail_off)

        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                self.pool.write_tokens(la, tail_pages, tail_off,
                                       k_new[:, 0], v_new[:, 0])
                k_pool, v_pool = self.pool.layer_pools(la)
                qb = q[:, 0]                                # (B, h, hd)
                o = self._attend(qb, k_pool, v_pool, window, B,
                                 tail_pages, tail_base, q_pos)
                y = L.dense(p["attn"]["wo"],
                            o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            elif kind.mixer == "mamba":
                states = self.mamba_state[j]
                conv = jnp.concatenate([states[r][0] for r in rows], 0)
                ssm = jnp.concatenate([states[r][1] for r in rows], 0)
                y, (conv_n, ssm_n) = M.mamba_decode(p["mamba"], cfg, h,
                                                    conv, ssm)
                for i, r in enumerate(rows):
                    states[r] = (conv_n[i:i + 1], ssm_n[i:i + 1])
                x = x + y
            if kind.ffn != "none":
                h2 = L.apply_norm(p["ln2"], x, cfg)
                if kind.ffn == "moe":
                    y2, _ = L.apply_moe(p["ffn"], cfg, h2)
                else:
                    y2 = L.apply_mlp(p["ffn"], cfg, h2)
                x = x + y2

        logits = T._unembed(self.params, cfg, x)[:, 0]      # (B, V)
        self.key, sk = jax.random.split(self.key)
        toks = np.asarray(sampler.sample(logits, sk, self.temperature))
        out = {}
        for i, r in enumerate(rows):
            req = self.requests[r]
            req.pending = int(toks[i])
            req.computed_hwm = max(req.computed_hwm, int(ctx[i]))
            out[r] = int(toks[i])
            if len(req.generated) >= req.max_new:
                req.state = DONE
        self.stats["steps"] += 1
        self.stats["decode_time"] += time.perf_counter() - t0
        return out

    def _attend(self, qb, k_pool, v_pool, window, B,
                tail_pages, tail_base, q_pos):
        plan, prepared = self._plans[window]
        # frozen part: backend partials over all full pages
        o_f, m_f, l_f = self._backend.partials(
            qb, k_pool, v_pool, plan, prepared, window=window)
        # tail part: each request's growing last page
        kt = k_pool[jnp.asarray(tail_pages)]
        vt = v_pool[jnp.asarray(tail_pages)]
        o_t, m_t, l_t = ops.single_page_attention(
            qb, kt, vt, tail_base, q_pos, window=window)
        o, _, _ = ref_mod.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
        return o.astype(qb.dtype)

    # ------------------------------------------------------------------ #
    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        return {r: req.generated for r, req in self.requests.items()}

    def release(self, rid: int) -> None:
        req = self.requests.pop(rid)
        if req.state == WAITING:
            self.admission.remove(rid)
            for nid in req.pinned:
                node = self.forest.nodes.get(nid)
                if node is not None:
                    node.meta["pins"] = node.meta.get("pins", 0) - 1
                    self._maybe_free_node(node)
            req.pinned = []
            return
        if rid in self._prefilling:
            self._prefilling.remove(rid)
        if rid in self.forest.leaf_of:
            self._release_kv(rid)
