"""Prefix-shared decode engine (the paper's vLLM-integration analogue).

Continuous-batching decode loop with CoDec as the attention backend,
organised as a small per-step state machine — **admit → prefill →
decode → evict** (DESIGN.md §6) — so the engine survives and exploits
memory pressure instead of raising ``MemoryError``:

* requests enter a FCFS **waiting queue**; admission is gated by a page
  watermark and a cost-model prefill budget (``core.scheduler.
  AdmissionController``), and long prompts are prefilled in **chunks**
  interleaved with decode steps;
* prompts are radix-inserted into a ``PrefixForest``; already-cached
  nodes are *not* recomputed (prefill prefix reuse) — only the new leaf's
  KV is computed, attending to the gathered cached prefix;
* decode attention = **frozen CoDec plan** over all full pages (rebuilt
  exactly when ``core.plan.plan_key`` changes: batch membership, path
  structure, or a leaf crossing a page boundary — the paper's "reuse a
  division plan for multiple decoding steps") POR-merged with a **tail
  attention** over each request's growing last page;
* when the paged pool runs dry the engine **preempts and recomputes**:
  the victim with the fewest generated tokens releases its non-shared
  pages, its shared prefix nodes stay pinned (``node.meta["pins"]``
  refcounts) and it re-enters the queue to be re-prefilled from the
  radix-cached prefix;
* Mamba layers (hybrid archs) keep per-request recurrent state, with
  end-of-node state caching so shared prefixes are also not recomputed
  for SSM mixers (the SSM analogue of prefix caching — see DESIGN.md §5);
* decode attention backends are resolved by NAME through
  ``kernels.registry`` (``codec-pallas`` / ``codec-xla`` / ``hydragen``
  prefix-shared, ``flash`` per-request baseline, ``ref`` oracle); the
  backend's ``prepare(plan)`` output is cached across steps and its
  ``partials`` are POR-merged with the tail-page attention — see
  DESIGN.md §2–§3 for the contract;
* with ``fused=True`` the whole decode step — scanned layer stack, KV
  tail writes, backend partials, POR merge, FFN, unembed, sampling —
  runs as ONE jitted, donated, shape-bucketed device dispatch per token
  (``serving/step_fn.py``), with asynchronous dispatch: the host defers
  sampled tokens as placeholders and syncs only at plan-rebuild /
  admission / eviction / completion boundaries
  (``flush_tokens``, DESIGN.md §8); backends that cannot trace
  (``ref``) transparently fall back to the eager per-layer path;
* with ``speculative=`` (``True`` or a ``speculation.SpecConfig``) each
  decode step becomes a draft-propose / tree-verify / accept-rollback
  loop (DESIGN.md §10): a deterministic n-gram proposer grows a bounded
  draft tree of ordinary forest nodes under each request's leaf, ONE
  multi-query dispatch scores every branch head through the backend
  registry (``core.plan.build_verify_plan`` — sibling branches share
  all ancestor KV reads), greedy acceptance commits the longest
  matching path (KV moves from draft pages to the leaf tail) and
  rollback releases the rejected draft pages — so several tokens can
  commit per dispatch while the committed stream stays byte-identical
  to non-speculative greedy decode;
* with ``mesh=`` (a ``(data, model)`` jax mesh) the engine serves SPMD
  (DESIGN.md §9): the KV pool shards pages over ``data`` and heads
  over ``model`` (``distributed.ShardedKVPool``, per-shard allocator
  invariants), plans are partitioned per data shard with sequence
  splits cut at shard boundaries (``core.plan.build_sharded_plan``),
  and the fused step traces under ``shard_map`` with a cross-device
  POR butterfly merge (``distributed/step_fn.py``) — token streams
  stay byte-identical to the single-device engine at any mesh shape.

Under greedy decoding the token streams are independent of memory
pressure: a preempted-and-recomputed request produces exactly the same
tokens as in an unconstrained run (asserted by the differential test
harness).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LayerKind, ModelConfig
from ..core import plan as plan_mod
from ..core import tree as tree_mod
from ..core.cost_model import CostModel
from ..core.scheduler import AdmissionController, AdmissionPolicy
from ..kernels import ops, ref as ref_mod, registry as registry_mod
from ..models import layers as L
from ..models import mamba as M
from ..models import transformer as T
from . import faults as faults_mod
from . import sampler, speculation as spec_mod, step_fn as step_fn_mod
from . import telemetry as telemetry_mod
from .cache import CachePolicy, PrefixCache
from .faults import EngineInvariantError, InjectedFault, ResourceExhausted
from .kv_cache import PagedKVPool

# request lifecycle states
WAITING, PREFILL, RUNNING, DONE = "waiting", "prefill", "running", "done"
# terminal failure states (DESIGN.md §12): the request is over, its KV
# released, and its stream closed via on_done(rid, reason)
CANCELLED, TIMED_OUT, FAILED = "cancelled", "timed_out", "failed"
TERMINAL = frozenset({DONE, CANCELLED, TIMED_OUT, FAILED})

# a sampled token that still lives in an un-synced device array
# (fused async dispatch); materialised by ``DecodeEngine.flush_tokens``
PENDING_DEVICE = "<device>"
_PLACEHOLDER = -1


class _Deferred:
    """One fused dispatch's sampled tokens, not yet on the host.

    ``patches`` records where each token was appended as a placeholder
    (request ``generated`` index + forest leaf token slot) so a later
    flush can write the real values in place.
    """

    __slots__ = ("tokens", "ok", "rows", "patches")

    def __init__(self, tokens, rows, ok=None):
        self.tokens = tokens          # (B_bucket,) device int32
        self.ok = ok                  # (B_bucket,) device bool, or None
        self.rows = rows              # request id per row
        self.patches = []             # (rid, row, gen_idx, node_id, tok_idx)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    generated: List[int] = dataclasses.field(default_factory=list)
    pending: Optional[int] = None      # sampled, not yet appended
    max_new: int = 16
    state: str = WAITING
    preemptions: int = 0
    computed_hwm: int = 0              # highest position this request ever computed
    pinned: List[int] = dataclasses.field(default_factory=list)
    kv_freed: bool = False             # done + KV reclaimed under pressure
    on_token: Optional[Any] = None     # streaming callback (rid, token)
    emitted: int = 0                   # tokens already streamed out
    on_done: Optional[Any] = None      # stream-close callback (rid, reason)
    submit_t: float = 0.0              # engine-clock time at add_request
    deadline: Optional[float] = None   # absolute end-to-end deadline
    queue_deadline: Optional[float] = None  # absolute admission deadline
    finish_reason: Optional[str] = None
    notified: bool = False             # on_done already fired
    # telemetry: engine-clock times a committed token value first/last
    # became host-visible (None until the first materialisation)
    first_tok_t: Optional[float] = None
    last_tok_t: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def finished(self) -> bool:
        return self.state in TERMINAL

    @property
    def seq(self) -> List[int]:
        """Full token sequence whose KV must be resident to decode."""
        return self.prompt + self.generated


def flat_layers(cfg: ModelConfig, params) -> List[Tuple[LayerKind, Dict]]:
    out = []
    if cfg.num_periods > 0:
        for pi in range(cfg.num_periods):
            period = jax.tree.map(lambda x: x[pi], params["blocks"])
            for i in range(cfg.period):
                out.append((cfg.layer_pattern[i], period[f"sub{i}"]))
    for i in range(cfg.remainder_layers):
        out.append((cfg.layer_pattern[i], params["rem"][i]))
    return out


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 page_size: int = 16, num_pages: int = 4096,
                 backend: str = "codec-pallas",
                 num_lanes: int = 2, max_q: int = 32,
                 max_kv_per_task: int = 2048,
                 replan_interval: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_chunk=None, reserve_pages: int = 0,
                 max_running: Optional[int] = None,
                 cascade: bool = False,
                 max_cascade_group: int = 8,
                 fused: bool = False,
                 mesh=None, seq_split_pages: int = 0,
                 replicate: bool = False, calibrate: bool = False,
                 speculative=None, cache=None,
                 faults=None, nan_guard: bool = False,
                 check_every: int = 0, clock=None,
                 max_dispatch_retries: int = 4,
                 telemetry=None):
        assert cfg.encoder_layers == 0, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self._backend = registry_mod.get(backend)
        if (cfg.sliding_window and not self._backend.supports_window
                and any(k.mixer == "attn_local"
                        for k in cfg.layer_pattern)):
            raise ValueError(f"backend {backend!r} cannot serve "
                             f"sliding-window layers")
        # ---- SPMD mesh mode (distributed/, DESIGN.md §9) -------------- #
        # mesh != None serves over a (data, model) device mesh: sharded
        # KV pool, per-shard plans, the whole step under shard_map.
        self.mesh = mesh
        if mesh is not None:
            if not fused:
                raise ValueError("mesh serving runs only the fused step; "
                                 "pass fused=True")
            if not (self._backend.jit_safe and self._backend.shardable):
                raise ValueError(
                    f"backend {backend!r} is not shardable; choose one of "
                    f"{registry_mod.names(shardable=True)}")
            D, M = mesh.shape["data"], mesh.shape["model"]
            if D & (D - 1):
                raise ValueError(f"data axis must be a power of two "
                                 f"(POR butterfly), got {D}")
            if M > 1 and (cfg.num_heads % M or cfg.num_kv_heads % M):
                raise ValueError(
                    f"model axis {M} must divide heads "
                    f"({cfg.num_heads} q / {cfg.num_kv_heads} kv)")
        self.page_size = page_size
        self.num_lanes = num_lanes
        self.max_q = max_q
        self.max_kv_per_task = max_kv_per_task
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        # ---- fault tolerance (serving/faults.py, DESIGN.md §12) ------- #
        # clock: injectable monotonic time source — deadlines are
        # enforced against it at step boundaries, so tests and the chaos
        # harness drive it deterministically (e.g. one unit per step)
        self.clock = clock if clock is not None else time.monotonic
        # ---- telemetry (serving/telemetry.py, DESIGN.md §13) ---------- #
        # telemetry=True builds a default Telemetry; pass an instance to
        # set profile_every / inject a TraceSink.  Span timestamps ride
        # the engine clock above, so fake clocks give deterministic
        # traces.  telemetry=None keeps every hook a no-op.
        if telemetry is True:
            telemetry = telemetry_mod.Telemetry()
        self.telemetry: Optional[telemetry_mod.Telemetry] = \
            telemetry or None
        if self.telemetry is not None:
            self.telemetry.bind_clock(self.clock)
        self.nan_guard = bool(nan_guard)
        if self.nan_guard and mesh is not None:
            raise ValueError(
                "nan_guard is not supported with mesh serving: the "
                "sharded step fn does not emit per-row finite flags")
        self.check_every = int(check_every)
        self.max_dispatch_retries = int(max_dispatch_retries)
        if faults is not None and not isinstance(faults,
                                                 faults_mod.FaultInjector):
            faults = faults_mod.FaultInjector(faults)
        self.injector: Optional[faults_mod.FaultInjector] = faults
        # admission-shrink rung of the degradation ladder: extra pages
        # the watermark holds back after repeated dispatch OOM
        self._backoff_pages = 0
        # (page, slot) pairs the nan_logits injector poisoned: scrubbed
        # when the target request is quarantined so a future tenant of
        # those pages can never read the NaNs
        self._nan_dirty: List[Tuple[int, int]] = []

        # ---- speculative tree-decoding mode (DESIGN.md §10) ----------- #
        # speculative=True (defaults) or a SpecConfig turns each decode
        # step into a draft-propose / tree-verify / accept-rollback loop:
        # multiple tokens commit per dispatch when the self-drafting
        # proposer guesses right.  Greedy-only, attention-only, and
        # single-device for now (sharded speculation: ROADMAP open item).
        if speculative is True:
            speculative = spec_mod.SpecConfig()
        self.spec: Optional[spec_mod.SpecConfig] = speculative or None
        if self.spec is not None:
            if temperature != 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only: acceptance "
                    "compares draft tokens against the argmax (lossless "
                    "speculative sampling is not implemented)")
            if any(k.mixer == "mamba" for k in cfg.layer_pattern):
                raise ValueError(
                    "speculative decoding needs KV-cache rollback; "
                    "recurrent (Mamba) state cannot be rolled back yet")
            if mesh is not None:
                raise ValueError(
                    "sharded speculation is not implemented "
                    "(ROADMAP open item); drop mesh= or speculative=")
        self.proposer = (spec_mod.NGramProposer(self.spec)
                         if self.spec else None)
        self._drafts: Dict[int, spec_mod.DraftState] = {}
        self._next_virt = -2          # virtual branch-head query ids

        self.layers = flat_layers(cfg, params)
        self.attn_layer_idx = {j: a for a, j in enumerate(
            j for j, (k, _) in enumerate(self.layers)
            if k.mixer in ("attn", "attn_local"))}
        n_attn = len(self.attn_layer_idx)
        if mesh is not None:
            from ..distributed.kv_pool import ShardedKVPool
            self.pool = ShardedKVPool(max(n_attn, 1), num_pages, page_size,
                                      max(cfg.num_kv_heads, 1),
                                      max(cfg.head_dim, 1), mesh=mesh,
                                      seq_split_pages=seq_split_pages)
        else:
            self.pool = PagedKVPool(max(n_attn, 1), num_pages, page_size,
                                    max(cfg.num_kv_heads, 1),
                                    max(cfg.head_dim, 1))
        # ---- replication-aware placement + measured-cost calibration -- #
        # replicate=True lets the sharded epoch copy hot short prefix
        # nodes onto EVERY data shard (extra pages instead of merge
        # wire — CostModel.replicate_gain decides per node); calibrate=
        # True blocks each dispatch to measure it and refits the cost
        # model's hardware coefficients from step_stats at plan epochs.
        self.replicate = bool(replicate) and mesh is not None \
            and mesh.shape["data"] > 1
        self.calibrate = bool(calibrate)
        self._epoch_features: Dict[str, float] = {}
        # per-shard feature vectors of the current epoch (profiled
        # sharded steps attach them for per-shard attribution)
        self._epoch_shard_features: Dict[str, List[float]] = {}
        self.forest = tree_mod.PrefixForest(page_size)
        # splitting a pinned node must extend each waiting holder's pin
        # list over the new lower half (see _on_split_pins)
        self.forest.on_split = self._on_split_pins
        # ---- persistent cross-request prefix cache (serving/cache.py) - #
        # cache=True (default policy) or a CachePolicy keeps finished
        # requests' prefix nodes resident: completed requests *detach*
        # instead of freeing, LRU/TTL eviction bounds residency, and
        # cached nodes are the first reclaim tier under pressure.
        # cache=None (default) preserves the closed-batch behaviour.
        if cache is True:
            cache = CachePolicy()
        self.cache: Optional[PrefixCache] = (
            PrefixCache(self.forest, cache) if cache is not None else None)
        # rolling snapshot so step_stats deltas also cover lookups from
        # eager admissions that happen between steps (add_request)
        self._cache_snap = dict(self.cache.stats) if self.cache else None
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.cost_model = CostModel(max(cfg.num_heads, 1),
                                    max(cfg.num_kv_heads, 1),
                                    max(cfg.head_dim, 1),
                                    page_size=page_size)
        self.policy = AdmissionPolicy(
            prefill_chunk=prefill_chunk, reserve_pages=reserve_pages,
            max_running=max_running,
            cascade=bool(cascade), max_cascade_group=max_cascade_group,
            draft_reserve_pages=self.spec.max_nodes if self.spec else 0)
        self.admission = AdmissionController(self.policy, self.cost_model,
                                             page_size)
        self._prefilling: List[int] = []   # admitted, prompt not fully prefilled
        # mamba per-request state, keyed by layer index
        self.mamba_state: Dict[int, Any] = {}
        # position the carried mamba state of a PREFILL request is valid at
        self._mamba_pos: Dict[int, int] = {}
        # plans keyed by window size (0 = full attention)
        self._plans: Dict[int, Any] = {}
        # mesh mode: last epoch's ShardedPlan per window (stats/bench)
        self._sharded_plans: Dict[int, Any] = {}
        self._plan_dirty = True
        self._plan_key: Optional[tuple] = None
        self.replan_interval = replan_interval
        self._steps_since_plan = 0
        self.stats = {"steps": 0, "replans": 0, "plan_time": 0.0,
                      "decode_time": 0.0, "decode_dispatch_time": 0.0,
                      "decode_sync_time": 0.0, "prefill_tokens": 0,
                      "admitted": 0, "preempted": 0, "reclaimed": 0,
                      "recompute_tokens": 0, "prefill_chunks": 0,
                      "prefill_stalls": 0, "fused_calls": 0,
                      "token_flushes": 0, "spec_steps": 0,
                      "spec_proposed": 0, "spec_accepted": 0,
                      "spec_draft_stalls": 0, "calibrations": 0,
                      "replica_promotions": 0, "replica_demotions": 0,
                      "cancelled": 0, "timed_out": 0, "failed": 0,
                      "callback_errors": 0, "faults_injected": 0,
                      "dispatch_failures": 0, "dispatch_recoveries": 0,
                      "nan_rows": 0, "invariant_checks": 0,
                      "cascade_groups": 0, "cascade_shared_tokens": 0,
                      "cascade_suffix_tokens": 0, "cascade_batches": 0}
        self.step_stats: List[Dict] = []
        self._decode_timing: Dict[str, float] = {}

        # ---- fused single-dispatch decode (serving/step_fn.py) -------- #
        # requested via ``fused=True``; active only for backends that
        # satisfy the registry's jit-safe partials contract (``ref``
        # falls back to the eager per-layer path).
        self.fused = bool(fused) and self._backend.jit_safe
        self._mamba_layer_js = [j for j, (k, _) in enumerate(self.layers)
                                if k.mixer == "mamba"]
        self._step_fn = None
        self._spec_step_fn = None
        self._replicated_sharding = None
        if self.fused and self.spec is not None:
            # speculative mode replaces the per-token decode dispatch
            # with the fused multi-query verification dispatch
            self._spec_step_fn = step_fn_mod.make_spec_step_fn(
                cfg, self._backend, tuple(self._windows()))
        elif self.fused and mesh is not None:
            from ..distributed import step_fn as sharded_step_fn_mod
            self._step_fn = sharded_step_fn_mod.make_sharded_step_fn(
                cfg, self._backend, tuple(self._windows()), temperature,
                mesh)
            # commit host-built step inputs to the replicated sharding so
            # the first dispatch and steady-state dispatches share one jit
            # signature (uncommitted vs replicated would compile twice)
            self._replicated_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            self.key = jax.device_put(self.key, self._replicated_sharding)
        elif self.fused:
            self._step_fn = step_fn_mod.make_step_fn(
                cfg, self._backend, tuple(self._windows()), temperature)
        # epoch state: valid between plan rebuilds
        self._fused_rows: Optional[List[int]] = None
        self._fused_base: Optional[step_fn_mod.StepBase] = None
        self._fused_prepared: Optional[tuple] = None
        self._fused_bucket = 0
        self._fused_delta = 0
        self._mamba_carry = None          # (conv_all, ssm_all) device stacks
        # async token plumbing
        self._deferred: List[_Deferred] = []
        self._pending_ref: Dict[int, Tuple[_Deferred, int]] = {}
        self._flushed_since_dispatch = True
        self._last_out: Optional[Tuple[List[int], Any]] = None
        # distinct fused shape signatures seen (compile-cache regression
        # tests bound the jit cache size by this set's size)
        self.bucket_signatures: set = set()

    # ------------------------------------------------------------------ #
    # request admission (admit phase) + chunked prefill (prefill phase)
    # ------------------------------------------------------------------ #
    def add_request(self, prompt: List[int], max_new: int = 16,
                    on_token=None, on_done=None,
                    deadline_s: Optional[float] = None,
                    max_queue_s: Optional[float] = None) -> int:
        """Enqueue a request; admits (and prefills) eagerly when memory
        allows, so under no pressure this behaves like immediate prefill.

        ``on_token(rid, token)`` streams each generated token as soon as
        its host value exists (immediately on the eager path; at sync
        boundaries on the fused async path).  ``on_done(rid, reason)``
        closes the stream exactly once with a terminal reason (``done``,
        ``cancelled``, ``deadline``, ``queue_timeout``, or a failure
        reason such as ``nan_logits`` / ``callback_error``).

        ``deadline_s`` bounds the request END TO END (queueing included)
        and ``max_queue_s`` bounds time spent WAITING; both are relative
        to now on the engine clock and enforced at step boundaries — an
        expired request transitions to ``TIMED_OUT`` with its KV
        released.  A deadline also promotes the request in the waiting
        queue (EDF ordering, ``core.scheduler.AdmissionController``).
        """
        prompt = list(prompt)
        if not prompt:
            raise ValueError("prompt must contain at least one token")
        if max_new <= 0:
            raise ValueError(f"max_new must be positive, got {max_new}")
        arr = np.asarray(prompt)
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"prompt must be integer token ids, got dtype {arr.dtype}")
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"prompt token id {lo if lo < 0 else hi} outside the "
                f"vocabulary [0, {self.cfg.vocab_size})")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be positive, got {deadline_s}")
        if max_queue_s is not None and max_queue_s <= 0:
            raise ValueError(
                f"max_queue_s must be positive, got {max_queue_s}")
        # only an *unservable* prompt is an error: whole-prompt prefill
        # needs every page at once, chunked prefill only one chunk + the
        # tail it grows into (larger prompts just wait in the queue)
        need = self.policy.min_working_pages(len(prompt), self.page_size)
        if need > self.pool.num_pages:
            raise MemoryError(
                f"prompt working set needs {need} KV pages but the pool "
                f"holds only {self.pool.num_pages}: it can never be "
                f"admitted")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(rid, prompt, max_new=max_new,
                      on_token=on_token, on_done=on_done, submit_t=now)
        if deadline_s is not None:
            req.deadline = now + float(deadline_s)
        if max_queue_s is not None:
            req.queue_deadline = now + float(max_queue_s)
        self.requests[rid] = req
        if self.telemetry is not None:
            self.telemetry.metrics["requests_submitted"].inc()
            self.telemetry.begin("queued", track=rid,
                                 args={"prompt_tokens": len(prompt),
                                       "max_new": max_new})
        edf = [d for d in (req.deadline, req.queue_deadline)
               if d is not None]
        self.admission.push(rid, deadline=min(edf) if edf else None)
        self._admit_phase()
        return rid

    def has_work(self) -> bool:
        return any(q.state in (WAITING, PREFILL, RUNNING)
                   for q in self.requests.values())

    def _live(self) -> List[int]:
        return [r for r in sorted(self.requests)
                if self.requests[r].state in (PREFILL, RUNNING)]

    def _active_rows(self) -> List[int]:
        return [r for r in sorted(self.requests)
                if self.requests[r].state == RUNNING]

    def _has_pages_for(self, req: Request) -> bool:
        seq = req.seq
        matched = self.forest.match_len(np.asarray(seq, np.int32))
        need = (-(-max(len(seq), 1) // self.page_size)
                - matched // self.page_size)
        # the draft reserve scales with *currently running* requests so an
        # idle engine always admits its head-of-line request (a reserve
        # counting the candidate itself could starve admission forever on
        # a pool barely larger than one working set).  _backoff_pages is
        # the degradation ladder's admission-shrink rung: after repeated
        # dispatch OOM the watermark rises so retries run with headroom.
        reserve = (self.policy.admission_reserve(len(self._active_rows()))
                   + self._backoff_pages)
        return self.pool.num_free - reserve >= need

    def _admit_phase(self) -> None:
        """Admission + chunked-prefill phase.

        Continues admitted prefills first, then admits waiting requests
        FCFS within the page watermark (reclaiming finished-request KV if
        needed) and the per-step cost-model prefill budget.  With
        ``cascade=True`` prefilling requests that share an unfilled
        forest node advance as one cascade group — the shared span is
        computed once, the suffix chunks batch into one dispatch — and
        admitting a head-of-line request pulls its cascade partners out
        of the wait queue so the group prefills together (DESIGN.md §14).
        """
        running_ctx = [self.forest.context_len(r)
                       for r in self._active_rows()]
        budget = self.admission.prefill_budget(running_ctx)
        # 1. advance chunked prefills already admitted
        spent = self._advance_prefills(budget)
        # 2. admit from the queue (FCFS; head-of-line blocks)
        while len(self.admission):
            if budget is not None and spent >= budget:
                return
            if (self.policy.max_running is not None
                    and len(self._live()) >= self.policy.max_running):
                return                      # capacity cap, not memory
            head = self.requests[self.admission.peek()]
            need_min = self.policy.min_working_pages(len(head.seq),
                                                     self.page_size)
            if need_min > self.pool.num_pages:
                raise MemoryError(
                    f"request {head.rid} needs a {need_min}-page working "
                    f"set but the pool holds only {self.pool.num_pages}")
            while not self._has_pages_for(head):
                if not self._reclaim_one(set(), allow_preempt=False):
                    return                  # no free memory: keep waiting
            # admission boundary: radix INSERTION compares token values,
            # so in-flight device tokens must land before _admit.  The
            # space probe above tolerates placeholders (-1 never equals
            # a real token, so match_len only under-matches and the page
            # need is over-estimated) — a head-of-line request blocked
            # on memory does NOT cost the fused path a sync per step.
            self.flush_tokens()
            self.admission.pop()
            self._admit(head)
            group = [head.rid]
            if self.policy.cascade:
                group += self._co_admit_partners(head)
            spent += self._prefill_group(
                group, None if budget is None else budget - spent)

    def _advance_prefills(self, budget: Optional[int]) -> int:
        """Advance every admitted-but-unfinished prefill by one chunk."""
        spent = 0
        if not self.policy.cascade:
            for rid in list(self._prefilling):
                if budget is not None and spent >= budget:
                    return spent
                req = self.requests[rid]
                if req.state != PREFILL:   # preempted by an earlier prefill
                    continue
                spent += self._prefill_step(
                    req, None if budget is None else budget - spent)
            return spent
        # cascade mode: regroup every step — membership is derived from
        # the forest (first unfilled node on each path), so preemption,
        # node splits and members completing at different times all fall
        # out of the grouping instead of needing group-object surgery
        for group in self._prefill_groups():
            if budget is not None and spent >= budget:
                return spent
            spent += self._prefill_group(
                group, None if budget is None else budget - spent)
        return spent

    def _co_admit_partners(self, head: Request) -> List[int]:
        """Pull the head's cascade partners out of the wait queue.

        A partner is a waiting request whose prompt's deepest shared
        forest node (``tree.match_path``) lies on the head's freshly
        inserted path: prefilling it now means the shared span is
        computed once for the whole group instead of once per request.
        Co-admission is opportunistic — the page probe and the
        ``max_running`` cap still apply, and a partner failing either
        simply keeps its place in the queue.
        """
        anchor = {n.id for n in self.forest.path(head.rid) if n.length}
        if not anchor:
            return []
        ps = self.page_size

        def key_of(rid: int) -> Optional[int]:
            nid, matched = self.forest.match_path(
                np.asarray(self.requests[rid].seq, np.int32))
            # < one page shared: insertion would not even split a node,
            # so there is no shared span to cascade over
            return nid if matched >= ps else None

        admitted: List[int] = []
        limit = self.policy.max_cascade_group - 1
        for rid in self.admission.cascade_partners(anchor, key_of, limit):
            if (self.policy.max_running is not None
                    and len(self._live()) >= self.policy.max_running):
                break
            part = self.requests[rid]
            if not self._has_pages_for(part):
                continue
            self.admission.remove(rid)
            self._admit(part)
            admitted.append(rid)
        return admitted

    def _cascade_key(self, rid: int) -> Optional[int]:
        """Id of the first not-fully-filled node on the request's path.

        Prefilling requests that map to the same key are about to compute
        the same node's KV — they form one cascade group and share that
        span's forward pass (``None`` = nothing left to fill).
        """
        for node in self.forest.path(rid):
            if node.length == 0:
                continue
            if min(node.meta.get("filled", 0), node.length) < node.length:
                return node.id
        return None

    def _prefill_groups(self) -> List[List[int]]:
        """Partition ``_prefilling`` into cascade groups (order kept)."""
        groups: List[List[int]] = []
        by_key: Dict[int, int] = {}
        for rid in list(self._prefilling):
            if self.requests[rid].state != PREFILL:
                continue
            key = self._cascade_key(rid)
            if key is not None and key in by_key:
                groups[by_key[key]].append(rid)
            else:
                if key is not None:
                    by_key[key] = len(groups)
                groups.append([rid])
        return groups

    def _prefill_group(self, group: List[int],
                       budget: Optional[int]) -> int:
        group = [r for r in group if self.requests[r].state == PREFILL]
        if not group:
            return 0
        if len(group) == 1:
            return self._prefill_step(self.requests[group[0]], budget)
        return self._cascade_prefill_step(group, budget)

    def _filled_front(self, rid: int) -> int:
        """Contiguous filled-KV front along the request's path."""
        filled = 0
        for node in self.forest.path(rid):
            f = min(node.meta.get("filled", 0), node.length)
            filled += f
            if f < node.length:
                break
        return filled

    def _shared_frontier(self, group: List[int]) -> int:
        """Absolute end position of the deepest node common to every
        member's path — the span whose compute the group shares."""
        paths = [self.forest.path(r) for r in group]
        end = 0
        for nodes in zip(*paths):
            nid = nodes[0].id
            if any(n.id != nid for n in nodes[1:]):
                break
            end = nodes[0].end_pos
        return end

    def _cascade_prefill_step(self, group: List[int],
                              budget: Optional[int]) -> int:
        """Advance a cascade group by one chunk (DESIGN.md §14).

        Phase A computes the group's shared uncached span exactly once:
        one forward over the common path (through the lead member), KV
        written into the shared nodes' pages and SSM boundary states
        cached in ``node.meta["ssm"]`` exactly as the sequential path
        does — then hands every sibling the carried mid-node SSM state so
        all of them resume identically from the chunk boundary.  Phase B
        batches the per-request suffix chunks into one padded dispatch;
        recurrent (Mamba) suffixes fall back to the per-request path
        (the shared phase still cascades), and members whose next
        unfilled node is shared with another member recurse as a deeper
        cascade subgroup.  A member stalling on pages is skipped while
        its siblings proceed; a stall on the *shared* span stalls the
        group (the span is on every member's path).
        """
        tm = self.telemetry
        spent = 0
        alive = [r for r in group if self.requests[r].state == PREFILL]
        if len(alive) < 2:
            return self._prefill_group(alive, budget)
        self.stats["cascade_groups"] += 1

        # ---- phase A: shared uncached span, computed once ------------- #
        lead = self.requests[alive[0]]
        frontier = self._shared_frontier(alive)
        if self._filled_front(lead.rid) < frontier:
            c0 = self.clock() if tm is not None else 0.0
            n = self._prefill_step(lead, budget, stop_at=frontier)
            spent += n
            if n:
                self.stats["cascade_shared_tokens"] += n * (len(alive) - 1)
                if tm is not None:
                    c1 = self.clock()
                    for rid in alive[1:]:
                        # the shared chunk belongs to every member's
                        # prefill span, not just the lead's (§13 nesting)
                        tm.complete("prefill_chunk", c0, c1, track=rid,
                                    args={"tokens": n, "shared": True})
            # hand each sibling the carried mid-node SSM state so hybrid
            # archs resume from the cascaded chunk boundary instead of
            # recomputing from the last node-aligned ``meta["ssm"]``
            pos = self._mamba_pos.get(lead.rid)
            if pos is not None and pos <= frontier:
                for rid in alive[1:]:
                    self._mamba_pos[rid] = pos
                    for st in self.mamba_state.values():
                        if lead.rid in st:
                            st[rid] = st[lead.rid]
            if n == 0:
                self.stats["prefill_stalls"] += len(alive) - 1
                return spent       # shared-span page stall: group waits
            if budget is not None and spent >= budget:
                return spent
            if self._filled_front(lead.rid) < frontier:
                return spent       # chunk ended mid-shared-span

        # ---- phase B: per-request suffix chunks, one dispatch --------- #
        alive = [r for r in alive if self.requests[r].state == PREFILL]
        has_mamba = any(k.mixer == "mamba" for k, _ in self.layers)
        by_key: Dict[int, List[int]] = {}
        for rid in alive:
            key = self._cascade_key(rid)
            by_key.setdefault(key if key is not None else ~rid,
                              []).append(rid)
        batch: List[Tuple[Request, int, int]] = []
        for key, rids in by_key.items():
            if budget is not None and spent >= budget:
                break
            left = None if budget is None else budget - spent
            if len(rids) > 1:
                # a deeper node shared by a strict subset of the group:
                # cascade it as its own subgroup (phase A recursion)
                spent += self._cascade_prefill_step(rids, left)
                continue
            req = self.requests[rids[0]]
            total = len(req.seq)
            start = self._filled_front(req.rid)
            if has_mamba or start >= total:
                # recurrent suffix / fully-cached prompt: per-request
                # path (promotion + final-logit recompute live there)
                spent += self._prefill_step(req, left)
                continue
            end = total if left is None else min(total, start + left)
            if not self._ensure_pages_upto(req.rid, end):
                self.stats["prefill_stalls"] += 1
                continue           # this member stalls; siblings proceed
            batch.append((req, start, end))
            spent += end - start
        if len(batch) == 1:
            req, start, end = batch[0]
            self._prefill_step(req, end - start)
        elif batch:
            self._batched_suffix_prefill(batch)
        return spent

    def _batched_suffix_prefill(
            self, batch: List[Tuple["Request", int, int]]) -> int:
        """One padded dispatch over several requests' suffix chunks.

        Cascade phase B: each row is a ``(request, start, end)`` span
        whose pages are already ensured and whose KV front is filled up
        to ``start``.  Rows pad to pow2 buckets (``core.plan.bucket_pow2``
        conventions — query length, KV length and batch); padded query
        slots carry position -1 (``L.mha`` masks them to a finite
        uniform), padded KV slots are masked via ``kv_valid``.  Per-row
        KV writes, sampling order and telemetry spans match the
        sequential per-request path.
        """
        cfg = self.cfg
        tm = self.telemetry
        c0 = self.clock() if tm is not None else 0.0
        B = len(batch)
        Tn = [end - start for _, start, end in batch]
        T_pad = plan_mod.bucket_pow2(max(Tn))
        S_pad = plan_mod.bucket_pow2(max(end for _, _, end in batch))
        B_pad = plan_mod.bucket_pow2(B)

        tok = np.zeros((B_pad, T_pad), np.int32)
        qpos = np.full((B_pad, T_pad), -1, np.int32)
        kv_valid = np.zeros((B_pad, S_pad), bool)
        for i, (req, start, end) in enumerate(batch):
            tok[i, :Tn[i]] = req.seq[start:end]
            qpos[i, :Tn[i]] = start + np.arange(Tn[i])
            kv_valid[i, :end] = True
        kv_pos = np.broadcast_to(np.arange(S_pad, dtype=np.int32),
                                 (B_pad, S_pad))
        qpos_j = jnp.asarray(qpos)

        paths = {req.rid: self.forest.path(req.rid) for req, _, _ in batch}
        segments: Dict[int, List[Tuple[Any, int, int]]] = {}
        for req, start, end in batch:
            segs, off = [], 0
            for node in paths[req.rid]:
                lo = max(0, off - start)
                hi = min(end, off + node.length) - start
                if hi > lo:
                    segs.append((node, lo, hi))
                off += node.length
            segments[req.rid] = segs

        x = T._embed(self.params, cfg, jnp.asarray(tok), qpos_j)
        new_kv_writes = []   # (layer_attn, k (B_pad,T_pad,kv,hd), v)
        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            la = self.attn_layer_idx[j]
            window = (cfg.sliding_window if kind.mixer == "attn_local"
                      else 0)
            q, k_new, v_new = L.attn_project(p["attn"], cfg, h, qpos_j)
            k_rows, v_rows = [], []
            for i, (req, start, end) in enumerate(batch):
                pk, pv = self._gather_prefix_upto(la, paths[req.rid],
                                                  start)
                kr = jnp.concatenate([pk.astype(k_new.dtype),
                                      k_new[i, :Tn[i]]], 0)
                vr = jnp.concatenate([pv.astype(v_new.dtype),
                                      v_new[i, :Tn[i]]], 0)
                pad = S_pad - kr.shape[0]
                if pad:
                    kr = jnp.pad(kr, ((0, pad), (0, 0), (0, 0)))
                    vr = jnp.pad(vr, ((0, pad), (0, 0), (0, 0)))
                k_rows.append(kr)
                v_rows.append(vr)
            k_all = jnp.stack(k_rows, 0)
            v_all = jnp.stack(v_rows, 0)
            if B_pad > B:
                zpad = ((0, B_pad - B), (0, 0), (0, 0), (0, 0))
                k_all = jnp.pad(k_all, zpad)
                v_all = jnp.pad(v_all, zpad)
            o = L.mha(q, k_all, v_all, causal=True, window=window,
                      softcap=cfg.attn_logit_softcap,
                      q_positions=qpos_j,
                      kv_positions=jnp.asarray(kv_pos),
                      kv_valid=jnp.asarray(kv_valid))
            y = L.dense(p["attn"]["wo"],
                        o.reshape(B_pad, T_pad,
                                  cfg.num_heads * cfg.head_dim))
            new_kv_writes.append((la, k_new, v_new))
            x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)

        # write each row's new KV into its own nodes' unfilled slots
        ps = self.page_size
        pages, offs, rows_b, rows_t = [], [], [], []
        for i, (req, start, end) in enumerate(batch):
            for node, lo, hi in segments[req.rid]:
                filled = node.meta.get("filled", 0)
                base = node.start_pos - start
                t_hi = hi - base
                reps = node.meta.get("replicas")
                page_lists = (list(reps.values()) if reps
                              else [node.page_ids])
                for t in range(max(filled, lo - base), t_hi):
                    for pl in page_lists:
                        pages.append(pl[t // ps])
                        offs.append(t % ps)
                        rows_b.append(i)
                        rows_t.append(base + t)
                if t_hi > filled:
                    node.meta["filled"] = t_hi
        if pages:
            bi = jnp.asarray(np.asarray(rows_b))
            ti = jnp.asarray(np.asarray(rows_t))
            for la, k_new, v_new in new_kv_writes:
                self.pool.write_tokens(la, np.asarray(pages),
                                       np.asarray(offs),
                                       k_new[bi, ti], v_new[bi, ti])

        done = sum(Tn)
        self.stats["prefill_tokens"] += done
        self.stats["cascade_suffix_tokens"] += done
        self.stats["cascade_batches"] += 1
        logits_all = None
        for i, (req, start, end) in enumerate(batch):
            self.stats["recompute_tokens"] += max(
                0, min(end, req.computed_hwm) - start)
            req.computed_hwm = max(req.computed_hwm, end)
            if end < len(req.seq):
                self.stats["prefill_chunks"] += 1
                continue
            if req.pending is None:
                if logits_all is None:
                    logits_all = T._unembed(self.params, cfg, x)
                logits = logits_all[i, Tn[i] - 1]
                self.key, sk = jax.random.split(self.key)
                req.pending = int(sampler.sample(logits[None], sk,
                                                 self.temperature)[0])
            self._promote(req)
        if tm is not None:
            c1 = self.clock()
            for i, (req, _, _) in enumerate(batch):
                tm.complete("prefill_chunk", c0, c1, track=req.rid,
                            args={"tokens": Tn[i], "batched": True})
            tm.observe("prefill_chunk_s", c1 - c0)
        return done

    def _admit(self, req: Request) -> None:
        """(Re-)insert the request's sequence into the forest and release
        the pins it held while waiting (its path now keeps those nodes
        alive by membership)."""
        seq = np.asarray(req.seq, np.int32)
        if (self.cache is not None and req.preemptions == 0
                and not req.generated):
            # first admission only: a preemption resume would count its
            # own pinned prefix as a "hit" and inflate the rate
            self.cache.record_lookup(self.forest.match_len(seq), len(seq))
        self.forest.insert_tokens(req.rid, seq)
        if self.cache is not None:
            for node in self.forest.path(req.rid):
                self.cache.stamp(node)
        for nid in req.pinned:
            node = self.forest.nodes.get(nid)
            if node is not None:
                node.meta["pins"] = node.meta.get("pins", 0) - 1
                self._maybe_free_node(node)
        req.pinned = []
        req.state = PREFILL
        self._prefilling.append(req.rid)
        self.stats["admitted"] += 1
        if self.telemetry is not None:
            if req.preemptions == 0 and not req.generated:
                self.telemetry.observe("queue_wait_s",
                                       self.clock() - req.submit_t)
            self.telemetry.end(track=req.rid)          # "queued"
            self.telemetry.begin("prefill", track=req.rid)

    # ------------------------------------------------------------------ #
    # async-token sync (fused path)
    # ------------------------------------------------------------------ #
    def flush_tokens(self) -> None:
        """Materialise every deferred device token on the host.

        The fused decode path appends sampled tokens to the forest and to
        ``Request.generated`` as placeholders while the device arrays are
        still in flight; this is the single blocking host⇄device sync
        point, invoked only at plan-rebuild / admission / eviction /
        completion boundaries (a no-op otherwise — the eager path never
        defers).
        """
        if not self._deferred and not self._pending_ref:
            return
        tm = self.telemetry
        c0 = self.clock() if tm is not None else 0.0
        t0 = time.perf_counter()
        vals = {id(e): np.asarray(e.tokens) for e in self._deferred}
        # NaN guard: a dispatch whose row_ok flag is False produced
        # non-finite logits for that row — every token of that request
        # from the first poisoned index on is garbage.  Quarantine the
        # request (FAILED) without touching the other rows.
        poisoned: Dict[int, int] = {}     # rid -> earliest bad gen index
        if self.nan_guard:
            for e in self._deferred:
                if e.ok is None:
                    continue
                okv = np.asarray(e.ok)
                for rid, row, gen_idx, _nid, _tid in e.patches:
                    if not bool(okv[row]) and gen_idx < poisoned.get(
                            rid, gen_idx + 1):
                        poisoned[rid] = gen_idx
            for rid, (e, row) in self._pending_ref.items():
                if (e.ok is not None and not bool(np.asarray(e.ok)[row])
                        and rid not in poisoned):
                    req = self.requests.get(rid)
                    if req is not None:   # sampled, never appended
                        poisoned[rid] = len(req.generated)
        landed: Set[int] = set()
        for e in self._deferred:
            v = vals[id(e)]
            for rid, row, gen_idx, node_id, tok_idx in e.patches:
                if gen_idx >= poisoned.get(rid, gen_idx + 1):
                    continue              # untrusted suffix: never lands
                tok = int(v[row])
                req = self.requests.get(rid)
                if req is not None and gen_idx < len(req.generated):
                    req.generated[gen_idx] = tok
                    landed.add(rid)
                node = self.forest.nodes.get(node_id)
                if (node is not None and node.tokens is not None
                        and tok_idx < len(node.tokens)):
                    node.tokens[tok_idx] = tok
        # sampled-but-not-yet-appended tokens become host ``pending``s
        for rid, (e, row) in self._pending_ref.items():
            req = self.requests.get(rid)
            if (req is not None and req.pending is PENDING_DEVICE
                    and rid not in poisoned):
                req.pending = int(vals[id(e)][row])
        self._deferred = []
        self._pending_ref = {}
        self._flushed_since_dispatch = True
        self.stats["token_flushes"] += 1
        elapsed = time.perf_counter() - t0
        self.stats["decode_sync_time"] += elapsed
        # the sync wait is attributed to the step in which the flush
        # actually ran, under its OWN key — it must never pollute that
        # step's dispatch/compute split (async flushing defers syncs to
        # arbitrary later steps; see step_stats "flush_time")
        self._decode_timing["flush_time"] = \
            self._decode_timing.get("flush_time", 0.0) + elapsed
        if tm is not None:
            for rid in landed:
                self._note_token(self.requests[rid])
            tm.observe("flush_s", elapsed)
            tm.complete("flush", c0, self.clock(),
                        args={"tokens": len(landed)})
        for rid, cut in poisoned.items():
            req = self.requests.get(rid)
            if req is None:
                continue
            del req.generated[cut:]       # placeholders only (never -1
            self.stats["nan_rows"] += 1   # streamed, so emitted <= cut)
            self._fail_request(rid, "nan_logits", flush=False)

    # ------------------------------------------------------------------ #
    # eviction (evict phase) / reclamation
    # ------------------------------------------------------------------ #
    def _maybe_free_node(self, node, force: bool = False) -> None:
        """Free a node once nothing references it: no requests pass
        through it, it has no children, and no evicted request pins it.

        With the prefix cache enabled, page-backed nodes are *retained*
        instead (they become cache content, reclaimed by TTL/LRU sweep
        or the pressure tier); ``force=True`` bypasses retention for
        callers that must actually free (pressure reclaim)."""
        if node.id == tree_mod.ROOT_ID or node.id not in self.forest.nodes:
            return
        if node.requests or node.children or node.meta.get("pins", 0) > 0:
            return
        if (not force and self.cache is not None
                and self.cache.retainable(node)):
            if "touch" not in node.meta:
                self.cache.stamp(node)
            return
        self._release_node_pages(node)
        parent = self.forest.nodes[node.parent]
        parent.children.remove(node.id)
        del self.forest.nodes[node.id]
        self._maybe_free_node(parent)

    def _release_kv(self, rid: int, force_leaf: bool = False) -> None:
        """Drop a request's forest footprint (finished or released).

        ``force_leaf=True`` (FAILED requests) bypasses cache retention
        for the request's PRIVATE leaf: its tail KV may be poisoned
        (NaN quarantine) or half-written, so it must never be served to
        a future prefix match.  Shared ancestors hold prompt KV written
        by prefill and stay retainable."""
        self._rollback_drafts(rid)
        leaf_id = self.forest.leaf_of.get(rid)
        for node in reversed(self.forest.path(rid)):
            if node.id not in self.forest.nodes:
                continue
            node.requests.remove(rid)
            self._maybe_free_node(node,
                                  force=force_leaf and node.id == leaf_id)
        del self.forest.leaf_of[rid]
        for st in self.mamba_state.values():
            st.pop(rid, None)
        self._mamba_pos.pop(rid, None)

    def _preempt(self, rid: int) -> None:
        """Evict a live request: release its non-shared pages, pin the
        shared prefix nodes it leaves behind, and requeue it (front) to be
        re-prefilled from the radix-cached prefix."""
        # re-prefill recomputes from token values; sync any deferred ones
        self.flush_tokens()
        # a victim evicted mid-speculation sheds its draft tree first:
        # draft nodes/virtual queries would otherwise keep its leaf (and
        # every ancestor) alive and leak the draft pages
        self._rollback_drafts(rid)
        req = self.requests[rid]
        assert req.state in (PREFILL, RUNNING), req.state
        if len(req.generated) >= req.max_new:
            # generation already complete (evicted between its final append
            # and the done transition): nothing to resume, just drop the KV
            self._release_kv(rid)
            if rid in self._prefilling:
                self._prefilling.remove(rid)
            req.state = DONE
            req.kv_freed = True
            self.stats["reclaimed"] += 1
            return
        pinned = []
        for node in reversed(self.forest.path(rid)):
            if node.id not in self.forest.nodes:
                continue
            node.requests.remove(rid)
            if (node.requests or node.children
                    or node.meta.get("pins", 0) > 0):
                node.meta["pins"] = node.meta.get("pins", 0) + 1
                pinned.append(node.id)
            else:
                self._release_node_pages(node)
                parent = self.forest.nodes[node.parent]
                parent.children.remove(node.id)
                del self.forest.nodes[node.id]
        del self.forest.leaf_of[rid]
        for st in self.mamba_state.values():
            st.pop(rid, None)
        self._mamba_pos.pop(rid, None)
        if rid in self._prefilling:
            self._prefilling.remove(rid)
        req.pinned = pinned
        req.state = WAITING
        req.preemptions += 1
        self.admission.requeue(rid)
        self.stats["preempted"] += 1
        if self.telemetry is not None:
            self.telemetry.end_all(rid)       # prefill/decode span
            self.telemetry.instant("evict", track=rid,
                                   args={"pinned_nodes": len(pinned)})
            self.telemetry.begin("queued", track=rid)

    def _reclaimable_pages(self, rid: int) -> int:
        """Pages that preempting ``rid`` would free (its non-shared nodes)."""
        n = 0
        freeable: Set[int] = set()
        for node in reversed(self.forest.path(rid)):
            # virtual branch-head queries (< 0) and draft children belong
            # to a live draft tree; preemption rolls the tree back first,
            # so they must not disqualify the victim (the estimate stays
            # conservative: draft pages themselves are not counted)
            others = [r for r in node.requests if r != rid and r >= 0]
            kids = {c for c in set(node.children) - freeable
                    if not self.forest.nodes[c].meta.get("draft")}
            if others or kids or node.meta.get("pins", 0) > 0:
                continue
            freeable.add(node.id)
            n += self._node_total_pages(node)
        return n

    def _reclaim_one(self, exclude: Set[int],
                     allow_preempt: bool = True) -> bool:
        """Free some pages, cheapest first: (0) evict cached (request-
        less, unpinned) prefix nodes LRU-first, (1) finished-request KV,
        (2) orphaned pinned nodes, (3) preempt the live victim with the
        fewest generated tokens (ties: latest arrival)."""
        if self.cache is not None and self._evict_cached(1) > 0:
            self.stats["reclaimed"] += 1
            return True
        for rid in sorted(self.requests):
            q = self.requests[rid]
            complete = (q.state == DONE
                        or (q.state == RUNNING
                            and len(q.generated) >= q.max_new))
            if (complete and not q.kv_freed and rid not in exclude
                    and rid in self.forest.leaf_of):
                self._release_kv(rid)
                q.state = DONE
                q.kv_freed = True
                self.stats["reclaimed"] += 1
                return True
        for rid in sorted(self.requests):
            q = self.requests[rid]
            if q.state != WAITING or not q.pinned:
                continue
            for nid in list(q.pinned):
                node = self.forest.nodes.get(nid)
                if node is None:
                    q.pinned.remove(nid)
                    continue
                if not node.requests and not node.children:
                    # drop this waiter's pin; the node frees once the last
                    # pin goes (multiply-pinned nodes shed one pin per
                    # holder until the final drop releases the pages)
                    q.pinned.remove(nid)
                    node.meta["pins"] = node.meta.get("pins", 0) - 1
                    self._maybe_free_node(node, force=True)
                    if nid not in self.forest.nodes:
                        self.stats["reclaimed"] += 1
                        return True
        # demote a replicated node (widest first): frees (D-1)/D of its
        # pages without touching any request — always cheaper than
        # preemption, and the plan rebuild re-derives the merge mask
        repl = [n for n in self.forest.nodes.values()
                if "replicas" in n.meta]
        if repl:
            self._demote_replicas(max(repl, key=lambda n: len(n.page_ids)))
            self.stats["reclaimed"] += 1
            return True
        if not allow_preempt:
            return False
        victims = [r for r in sorted(self.requests)
                   if self.requests[r].state in (PREFILL, RUNNING)
                   and r not in exclude
                   and self._reclaimable_pages(r) > 0]
        if not victims:
            return False
        victim = min(victims,
                     key=lambda r: (len(self.requests[r].generated), -r))
        self._preempt(victim)
        return True

    # ------------------------------------------------------------------ #
    # persistent cross-request prefix cache (serving/cache.py)
    # ------------------------------------------------------------------ #
    def _on_split_pins(self, upper, lower) -> None:
        """Forest split observer: ``tree._split`` copies the pin
        refcount to the lower half; the per-request pin *lists* must
        follow, or un-pinning at re-admission would strand the lower
        half pinned forever."""
        # a replicated node splits every replica run at the same page
        # boundary (``tree._split`` already cut ``page_ids``, which hold
        # the primary's rows — the other shards' runs must follow, or
        # the lower half would alias the upper's replica pages)
        reps = upper.meta.get("replicas")
        if reps is not None:
            cut = len(upper.page_ids)
            prim = upper.meta["replica_primary"]
            lower.meta["replicas"] = {s: lst[cut:]
                                      for s, lst in reps.items()}
            upper.meta["replicas"] = {s: lst[:cut]
                                      for s, lst in reps.items()}
            lower.meta["replica_primary"] = prim
            upper.page_ids = list(upper.meta["replicas"][prim])
            lower.page_ids = list(lower.meta["replicas"][prim])
        if upper.meta.get("pins", 0) <= 0:
            return
        for req in self.requests.values():
            if upper.id in req.pinned:
                req.pinned.append(lower.id)

    def _free_cached_node(self, node) -> None:
        """Evict one cached leaf: release its pages and unlink it (the
        parent becomes a future candidate under its own touch stamp)."""
        self.cache.stats["evicted_nodes"] += 1
        self.cache.stats["evicted_pages"] += self._node_total_pages(node)
        self._release_node_pages(node)
        parent = self.forest.nodes[node.parent]
        parent.children.remove(node.id)
        del self.forest.nodes[node.id]
        self._maybe_free_node(parent)   # frees empty husks, keeps cache

    def _evict_cached(self, min_pages: int) -> int:
        """Evict LRU cache entries until >= ``min_pages`` pages freed
        (or the cache is empty); returns pages actually freed."""
        freed = 0
        while freed < min_pages:
            cands = self.cache.candidates()
            if not cands:
                break
            node = cands[0]
            freed += len(node.page_ids)
            self._free_cached_node(node)
        return freed

    def _detach_finished(self) -> None:
        """Detach completed requests from the forest, retaining their
        page-backed prefix nodes as cache (the tentpole behaviour: a
        finished request's system prompt stays resident for the next
        request that shares it)."""
        done = [r for r in sorted(self.requests)
                if self.requests[r].state == DONE
                and not self.requests[r].kv_freed
                and r in self.forest.leaf_of]
        if not done:
            return
        # cached node tokens are matched by VALUE at future admissions;
        # any in-flight placeholders must land first
        self.flush_tokens()
        for rid in done:
            self._rollback_drafts(rid)
            path = self.forest.path(rid)
            self.forest.detach_request(rid)
            for node in reversed(path):
                if node.id in self.forest.nodes:
                    self._maybe_free_node(node)
            for st in self.mamba_state.values():
                st.pop(rid, None)
            self._mamba_pos.pop(rid, None)
            self.requests[rid].kv_freed = True

    def _cache_sweep(self) -> None:
        """Per-step TTL expiry + LRU enforcement of ``max_pages``."""
        while True:
            expired = [n for n in self.cache.expired()
                       if n.id in self.forest.nodes]
            if not expired:
                break
            for node in expired:    # parents become leaves next round
                if node.id in self.forest.nodes:
                    self._free_cached_node(node)
        over = self.cache.over_cap()
        if over > 0:
            self._evict_cached(over)

    def _stream_ready(self) -> None:
        """Deliver newly-materialised tokens to streaming callbacks
        (stops at the first still-deferred placeholder, so fused-mode
        streams arrive at sync boundaries, in order).

        User callbacks are ISOLATED: one raising ``on_token`` marks only
        that request FAILED (reason ``callback_error``) — the engine
        step, the batch, and every other stream are unaffected."""
        for req in list(self.requests.values()):
            if req.on_token is None:
                continue
            gen = req.generated
            while req.emitted < len(gen) and gen[req.emitted] >= 0:
                tok = gen[req.emitted]
                req.emitted += 1
                try:
                    if self.injector is not None:
                        spec = self.injector.take("callback", rid=req.rid)
                        if spec is not None:
                            self.stats["faults_injected"] += 1
                            raise InjectedFault(
                                spec, f"injected on_token failure for "
                                      f"request {req.rid}")
                    req.on_token(req.rid, tok)
                except Exception:
                    self.stats["callback_errors"] += 1
                    self._fail_request(req.rid, "callback_error",
                                       flush=False)
                    break

    def _alloc_pages(self, n: int, exclude: Set[int],
                     allow_preempt: bool = True,
                     hint: Optional[int] = None) -> Optional[List[int]]:
        """Allocate ``n`` pages, evicting under pressure; ``None`` when
        nothing more can be reclaimed (caller stalls or raises).
        ``hint`` (node id) is the sharded pool's placement affinity."""
        if (self.injector is not None and self.spec is None
                and self.injector.take("alloc") is not None):
            # simulated transient exhaustion: callers degrade exactly as
            # under real pressure (stall / preempt-and-recompute), so no
            # committed stream changes.  Gated off in speculative mode:
            # a mid-commit allocation failure there has no clean unwind.
            self.stats["faults_injected"] += 1
            return None
        while self.pool.num_free < n:
            if not self._reclaim_one(exclude, allow_preempt):
                return None
        return self.pool.allocator.alloc(n, hint=hint)

    # ------------------------------------------------------------------ #
    # replication-aware placement (mesh mode): hot short prefix nodes
    # are copied onto EVERY data shard so their rows skip the cross-
    # shard POR merge entirely (core.plan.replicated_node_set decides
    # which rows actually may — a row must be replicated along its WHOLE
    # path, or the merge would LSE-double-count the shared partials)
    # ------------------------------------------------------------------ #
    def _node_total_pages(self, node) -> int:
        """Pool pages the node holds across all shards (replica-aware)."""
        reps = node.meta.get("replicas")
        if reps is not None:
            return sum(len(v) for v in reps.values())
        return len(node.page_ids)

    def _release_node_pages(self, node) -> None:
        """Release every page the node holds (all replicas, or the
        single placement).  ``page_ids`` aliases the primary replica run,
        so replicated nodes must NOT release it separately."""
        reps = node.meta.pop("replicas", None)
        node.meta.pop("replica_primary", None)
        if reps is not None:
            for rws in reps.values():
                self.pool.allocator.release(rws)
        elif node.page_ids:
            self.pool.allocator.release(node.page_ids)
        node.page_ids = []

    def _promote_replicas(self, node) -> bool:
        """Copy a node's KV onto every shard and free its old placement.

        The old pages may be released immediately after the (value-
        semantics) device copy: nothing in the engine retains node pages
        beyond the node itself, so their refcount is 1 by construction.
        """
        alloc = self.pool.allocator
        D = self.pool.num_shards
        n = len(node.page_ids)
        # same tie-break as alloc_replicas' affinity pin
        primary = max(range(D),
                      key=lambda i: (alloc.shards[i].num_free, -i))
        try:
            reps = alloc.alloc_replicas(n, hint=node.id)
        except MemoryError:
            return False
        src = np.asarray(node.page_ids, np.int64)
        dst = np.concatenate([np.asarray(reps[s], np.int64)
                              for s in range(D)])
        srcs = np.tile(src, D)
        self.pool.k = self.pool.k.at[:, dst].set(self.pool.k[:, srcs])
        self.pool.v = self.pool.v.at[:, dst].set(self.pool.v[:, srcs])
        alloc.release(node.page_ids)
        node.meta["replicas"] = reps
        node.meta["replica_primary"] = primary
        node.page_ids = list(reps[primary])
        self.stats["replica_promotions"] += 1
        self._plan_dirty = True
        return True

    def _demote_replicas(self, node) -> None:
        """Back to single placement: keep the primary run, free the rest.
        The running plan's page remaps reference the freed rows, so the
        plan is marked dirty and rebuilt before the next dispatch."""
        reps = node.meta.pop("replicas", None)
        if reps is None:
            return
        primary = node.meta.pop("replica_primary")
        for s, rws in reps.items():
            if s != primary:
                self.pool.allocator.release(rws)
        node.page_ids = list(reps[primary])
        self.stats["replica_demotions"] += 1
        self._plan_dirty = True

    def _replication_sweep(self, rows: List[int]) -> None:
        """Promote nodes whose merge saving beats their extra read cost
        (``CostModel.replicate_gain``), headroom permitting: each shard
        must fit the node AND a page of tail growth per active row."""
        alloc = self.pool.allocator
        D = self.pool.num_shards
        rowset = set(rows)
        seen: Set[int] = set()
        for r in rows:
            for node in self.forest.path(r):
                if node.id in seen:
                    continue
                seen.add(node.id)
                if (not node.page_ids or "replicas" in node.meta
                        or node.meta.get("draft")):
                    continue
                n_q = sum(1 for q in node.requests if q in rowset)
                if n_q == 0:
                    continue
                if self.cost_model.replicate_gain(n_q, node.length, D) <= 0:
                    continue
                n = len(node.page_ids)
                if min(s.num_free for s in alloc.shards) < n + len(rows):
                    continue
                self._promote_replicas(node)

    def _grow_node_pages(self, node, k: int,
                         exclude: Set[int]) -> Optional[List[int]]:
        """Grow a node by ``k`` pages, replica-aware: replicated nodes
        grow on every shard (all-or-nothing), demoting to the primary
        placement when some shard cannot fit — the ordinary reclaiming
        allocator then takes over.  Returns the primary's new rows."""
        reps = node.meta.get("replicas")
        if reps is not None:
            try:
                new = self.pool.allocator.alloc_replicas(k, hint=node.id)
            except MemoryError:
                self._demote_replicas(node)
            else:
                for s, rws in new.items():
                    reps[s].extend(rws)
                primary = node.meta["replica_primary"]
                node.page_ids = list(reps[primary])
                return new[primary]
        got = self._alloc_pages(k, exclude, hint=node.id)
        if got is not None:
            node.page_ids += got
        return got

    # ------------------------------------------------------------------ #
    # measured-cost calibration: refit the cost model's hardware
    # coefficients from the step timings already in ``step_stats``
    # ------------------------------------------------------------------ #
    def recalibrate(self, min_samples: int = 8) -> bool:
        """Fit ``CostModel`` coefficients from measured sharded steps.

        Mesh steps record their plan's feature counts (``hbm_bytes``,
        ``grid_steps``, ``merge_bytes``, ``merge_rounds``) next to the
        measured ``dispatch_time`` (which, under ``calibrate=True``,
        blocks on the device and is the true step wall time).  The fit
        replaces datasheet bandwidths/overheads, so subsequent division,
        lane balancing and replicate-vs-split decisions use measured
        costs.  Steps that hit a compile or an epoch replan are orders
        of magnitude above the steady state and would poison the
        regression, so samples beyond 5x the median step time are
        rejected first.  Returns True when a fit was installed.

        Sampled-profiling rows (``telemetry.profile_every``) carry a
        blocked dispatch/compute split even when ``calibrate=`` is off;
        when any exist they are PREFERRED over plain rows, whose
        ``dispatch_time`` on the async fused path is only the submit
        cost and would poison the fit."""
        rows = [s for s in self.step_stats if s.get("hbm_bytes")]
        profiled = [s for s in rows if s.get("profiled")]
        pool = profiled or [s for s in rows
                            if s.get("dispatch_time", 0) > 0]
        samples = [{**s, "seconds": s["dispatch_time"]
                    + (s.get("compute_time", 0.0)
                       if s.get("profiled") else 0.0)}
                   for s in pool if s.get("dispatch_time", 0) > 0
                   or s.get("compute_time", 0) > 0]
        if samples:
            med = float(np.median([s["seconds"] for s in samples]))
            samples = [s for s in samples if s["seconds"] <= 5.0 * med]
        if self.cost_model.fit(samples, min_samples=min_samples):
            self.stats["calibrations"] += 1
            return True
        return False

    # ------------------------------------------------------------------ #
    # prefill with prefix reuse (chunked, resumable)
    # ------------------------------------------------------------------ #
    def _ensure_pages_upto(self, rid: int, upto: int) -> bool:
        """Allocate pages covering tokens [0, upto) of the path; False when
        allocation stalls (partial allocations are kept for the retry)."""
        for node in self.forest.path(rid):
            cover = min(node.length, max(0, upto - node.start_pos))
            need = -(-cover // self.page_size)
            if len(node.page_ids) < need:
                got = self._grow_node_pages(node,
                                            need - len(node.page_ids),
                                            exclude={rid})
                if got is None:
                    return False
        return True

    def _gather_prefix_upto(self, layer_attn: int, path, upto: int) -> Tuple:
        """Dense (upto, n_kv, hd) of the path's first ``upto`` cached tokens."""
        ks, vs = [], []
        pos = 0
        for node in path:
            take = min(node.length, upto - pos)
            if take <= 0:
                break
            npg = -(-take // self.page_size)
            k, v = self.pool.gather_context(layer_attn,
                                            node.page_ids[:npg], take)
            ks.append(k)
            vs.append(v)
            pos += take
        if not ks:
            hkv, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            z = jnp.zeros((0, hkv, hd), self.pool.k.dtype)
            return z, z
        return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)

    def _promote(self, req: Request) -> None:
        req.state = RUNNING
        if req.rid in self._prefilling:
            self._prefilling.remove(req.rid)
        self._mamba_pos.pop(req.rid, None)
        if self.telemetry is not None:
            self.telemetry.end(track=req.rid)         # "prefill"
            self.telemetry.begin("decode", track=req.rid)

    def _note_token(self, req: Request) -> None:
        """Telemetry bookkeeping: a committed token value for this
        request just became host-visible (TTFT/TPOT attribution)."""
        now = self.clock()
        if req.first_tok_t is None:
            req.first_tok_t = now
        req.last_tok_t = now

    def _prefill_step(self, req: Request, budget: Optional[int],
                      stop_at: Optional[int] = None) -> int:
        """Advance the request's prefill by one chunk of ``<= budget``
        tokens (``None`` = the whole remaining prompt); returns tokens
        computed (0 = stalled on pages, retried next step).  ``stop_at``
        additionally caps the chunk at an absolute position — cascade
        phase A uses it to stop exactly at the group's shared-path
        frontier (DESIGN.md §14).

        Attention KV of the cached prefix is reused (gathered from the
        paged pool); SSM layers resume from the deepest cached boundary —
        the carried chunk state, else a node-boundary ``meta["ssm"]``
        cache — and states are (re-)cached at every shared-node boundary
        inside the recomputed span so later siblings resume exactly.
        When the sequence completes, the request joins the decode batch;
        ``pending`` is sampled only if it did not survive a preemption.
        """
        cfg = self.cfg
        rid = req.rid
        seq = req.seq
        total = len(seq)
        path = self.forest.path(rid)

        # contiguous filled-KV front along the path
        kv_filled = self._filled_front(rid)

        has_mamba = any(k.mixer == "mamba" for k, _ in self.layers)

        if kv_filled < total:
            attn_start = kv_filled
        elif req.pending is None:
            # fully cached prompt: recompute only the final position so
            # its logits exist — the KV itself is resident and nothing
            # needs rewriting.  Recurrent archs still rewind to the
            # deepest cached SSM boundary (mamba_start below), so hybrid
            # spans stay bounded by the last node, not the whole prompt.
            attn_start = total - 1
        else:
            attn_start = total

        mamba_init: Dict[int, Any] = {}
        mamba_start = 0
        if has_mamba:
            carried = self._mamba_pos.get(rid)
            if carried is not None and carried == attn_start:
                mamba_start = carried
                mamba_init = {j: st[rid]
                              for j, st in self.mamba_state.items()
                              if rid in st}
            else:
                pos = 0
                for node in path:
                    f = min(node.meta.get("filled", 0), node.length)
                    pos += node.length
                    if f < node.length or pos > attn_start:
                        break
                    if "ssm" in node.meta:
                        mamba_start, mamba_init = pos, node.meta["ssm"]

        if attn_start >= total and (not has_mamba or mamba_start >= total):
            self._promote(req)
            return 0

        span_start = min(attn_start, mamba_start) if has_mamba \
            else attn_start
        end = total if budget is None else min(
            total, max(span_start + max(budget, 1), kv_filled + 1))
        if stop_at is not None and stop_at < end:
            # never regress below the minimum-progress floor above
            end = max(stop_at, min(kv_filled + 1, total))

        if not self._ensure_pages_upto(rid, end):
            self.stats["prefill_stalls"] += 1
            return 0
        c0 = self.clock() if self.telemetry is not None else 0.0

        tokens = np.asarray(seq[span_start:end], np.int32)
        Tn = len(tokens)
        positions = (span_start + np.arange(Tn))[None]           # (1, Tn)

        # node segments covering the span (for KV writes + state caching)
        segments = []        # (node, lo, hi) in span-local coordinates
        off = 0
        for node in path:
            lo = max(0, off - span_start)
            hi = min(end, off + node.length) - span_start
            if hi > lo:
                segments.append((node, lo, hi))
            off += node.length

        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None],
                     jnp.asarray(positions))
        leaf_id = self.forest.leaf_of[rid]

        new_kv_writes = []  # (layer_attn, k (Tn,kv,hd), v)
        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 jnp.asarray(positions))
                pk, pv = self._gather_prefix_upto(la, path, span_start)
                k_all = jnp.concatenate([pk.astype(k_new.dtype)[None],
                                         k_new], 1)
                v_all = jnp.concatenate([pv.astype(v_new.dtype)[None],
                                         v_new], 1)
                o = L.mha(q, k_all, v_all, causal=True, window=window,
                          softcap=cfg.attn_logit_softcap,
                          q_positions=jnp.asarray(positions),
                          kv_positions=jnp.arange(end)[None])
                y = L.dense(p["attn"]["wo"],
                            o.reshape(1, Tn, cfg.num_heads * cfg.head_dim))
                new_kv_writes.append((la, k_new[0], v_new[0]))
                x = x + y
            elif kind.mixer == "mamba":
                state = mamba_init.get(j)
                ys = []
                for node, lo, hi in segments:
                    y_seg, state = self._mamba_prefill(p["mamba"],
                                                       h[:, lo:hi], state)
                    ys.append(y_seg)
                    # cache end-of-node state (shared nodes only, and only
                    # when the chunk reaches the node boundary; a leaf's
                    # state keeps moving, carried per request below)
                    if (node.id != leaf_id
                            and span_start + hi == node.end_pos):
                        node.meta.setdefault("ssm", {})[j] = state
                y = jnp.concatenate(ys, 1)
                self.mamba_state.setdefault(j, {})[rid] = state
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)

        # write new KV into unfilled page slots only
        offs, pages, kv_rows = [], [], []
        ps = self.page_size
        for node, lo, hi in segments:
            start = node.meta.get("filled", 0)
            base = node.start_pos - span_start   # span-local index of token 0
            t_hi = hi - base
            # a replicated node's KV lands in EVERY shard's replica run
            # (same source row scattered to each), keeping replicas
            # bitwise in sync with the primary
            reps = node.meta.get("replicas")
            page_lists = list(reps.values()) if reps else [node.page_ids]
            for t in range(max(start, lo - base), t_hi):
                for pl in page_lists:
                    pages.append(pl[t // ps])
                    offs.append(t % ps)
                    kv_rows.append(base + t)
            if t_hi > start:
                node.meta["filled"] = t_hi
        if kv_rows:
            rows = jnp.asarray(np.asarray(kv_rows))
            for la, k_new, v_new in new_kv_writes:
                self.pool.write_tokens(la, np.asarray(pages),
                                       np.asarray(offs),
                                       k_new[rows], v_new[rows])

        self.stats["prefill_tokens"] += Tn
        self.stats["recompute_tokens"] += max(
            0, min(end, req.computed_hwm) - span_start)
        req.computed_hwm = max(req.computed_hwm, end)
        if self.telemetry is not None:
            c1 = self.clock()
            self.telemetry.complete("prefill_chunk", c0, c1, track=rid,
                                    args={"tokens": Tn})
            self.telemetry.observe("prefill_chunk_s", c1 - c0)

        if end < total:
            self.stats["prefill_chunks"] += 1
            if has_mamba:
                self._mamba_pos[rid] = end
            return Tn

        if req.pending is None:
            logits = T._unembed(self.params, cfg, x)[0, -1]
            self.key, sk = jax.random.split(self.key)
            req.pending = int(sampler.sample(logits[None], sk,
                                             self.temperature)[0])
        self._promote(req)
        return Tn

    def _mamba_prefill(self, p, h, init):
        cfg = self.cfg
        if init is None:
            return M.mamba_forward(p, cfg, h)
        conv0, ssm0 = init
        # run chunked SSD from a carried state
        zxbcdt = h @ p["in_proj"]["w"]
        z, xBC_raw, dt = M._split_proj(cfg, zxbcdt)
        xBC = M._causal_conv(xBC_raw, p["conv_w"], p["conv_b"],
                             init_state=conv0)
        d_in, S = cfg.d_inner, cfg.ssm_state
        B, Tn = h.shape[0], h.shape[1]
        x_ssm = xBC[..., :d_in].reshape(B, Tn, cfg.ssm_heads,
                                        cfg.ssm_head_dim)
        Bm = xBC[..., d_in:d_in + S]
        Cm = xBC[..., d_in + S:]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, final = M.ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 init_state=ssm0)
        y = y + x_ssm.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
        y = y.reshape(B, Tn, d_in)
        y = M._gated_norm(y, z, p["norm"], cfg.norm_eps)
        out = y @ p["out_proj"]["w"]
        K = cfg.ssm_conv
        conv_tail = jnp.concatenate([conv0, xBC_raw.astype(jnp.float32)],
                                    1)[:, -(K - 1):]
        return out, (conv_tail, final)

    # ------------------------------------------------------------------ #
    # plan management
    # ------------------------------------------------------------------ #
    def _windows(self) -> List[int]:
        ws = set()
        for kind, _ in self.layers:
            if kind.mixer == "attn":
                ws.add(0)
            elif kind.mixer == "attn_local":
                ws.add(self.cfg.sliding_window)
        return sorted(ws)

    @property
    def plan_rebuilds(self) -> int:
        """Rebuild counter (the plan-lifecycle tests consume this)."""
        return self.stats["replans"]

    @property
    def fused_cache_size(self) -> int:
        """Compiled fused-step program count (jit cache entries); the
        compile-cache regression test bounds this by the number of
        distinct ``bucket_signatures``."""
        # _cache_size is a private jax API (stable across the pinned
        # 0.4.x line); degrade to 0 rather than crash stats printing if
        # a future jax renames it
        fn = self._step_fn if self._step_fn is not None \
            else self._spec_step_fn
        size = getattr(fn, "_cache_size", None)
        return int(size()) if callable(size) else 0

    def _rebuild_plans(self) -> None:
        t0 = time.perf_counter()
        c0 = self.clock() if self.telemetry is not None else 0.0
        rows = self._active_rows()
        req_rows = {r: i for i, r in enumerate(rows)}
        ps = self.page_size
        truncate = {}
        for r in rows:
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tail_start = max(0, ((leaf.length - 1) // ps) * ps)
            truncate[leaf.id] = tail_start
        build = (plan_mod.flash_plan if self._backend.plan_kind == "flash"
                 else plan_mod.build_plan)
        self._plans = {}
        for w in self._windows():
            p = build(
                self.forest, self.cost_model, self.num_lanes, self.max_q,
                self.max_kv_per_task, req_rows=req_rows, window=w,
                truncate=truncate)
            p = plan_mod.pad_plan(p)
            self._plans[w] = (p, self._backend.prepare(p))
        self._plan_key = plan_mod.plan_key(self.forest, rows)
        self._plan_dirty = False
        self._steps_since_plan = 0
        self.stats["replans"] += 1
        self.stats["plan_time"] += time.perf_counter() - t0
        if self.telemetry is not None:
            c1 = self.clock()
            self.telemetry.complete("plan_build", c0, c1,
                                    args={"rows": len(rows)})
            self.telemetry.observe("plan_build_s", c1 - c0)

    def _advance_qpos(self) -> None:
        """Cheap per-step plan refresh: live queries moved one position."""
        for w, (p, _) in list(self._plans.items()):
            slot = np.arange(p.max_q)[None, :]
            live = slot < p.task_qnum[:, None]
            p.q_pos = p.q_pos + live.astype(np.int32)
            self._plans[w] = (p, self._backend.prepare(p))

    # ------------------------------------------------------------------ #
    # decode step (admit -> prefill -> decode -> evict state machine)
    # ------------------------------------------------------------------ #
    def step(self) -> Dict[int, int]:
        """One engine step: admission + chunked prefill, then append
        pending tokens (evicting under pressure) and decode one token per
        running request."""
        tm = self.telemetry
        snap = {k: self.stats[k]
                for k in ("steps", "admitted", "preempted", "reclaimed",
                          "prefill_tokens", "recompute_tokens",
                          "spec_proposed", "spec_accepted",
                          "cancelled", "timed_out", "failed",
                          "callback_errors", "faults_injected",
                          "dispatch_failures", "dispatch_recoveries")}
        # per-step timing starts HERE: a flush triggered by deadline
        # enforcement or between-step admission bills this step's
        # flush_time, never the decode phase's dispatch/compute split
        self._decode_timing = {}
        if tm is not None:
            c_step0 = self.clock()
            tm.begin("step", args={"step": len(self.step_stats)})
        if self.injector is not None:
            self.injector.tick(len(self.step_stats))
        self._enforce_deadlines()
        self._admit_phase()
        out = self._decode_with_recovery()
        if self.cache is not None:
            self.cache.tick()
            self._detach_finished()
            self._cache_sweep()
        self._stream_ready()
        self._notify_done()
        if self.check_every and (len(self.step_stats) + 1) \
                % self.check_every == 0:
            self.check()
        cache_stats = {}
        if self.cache is not None:
            d = self._cache_step_delta()
            resident = self.cache.resident_pages()
            cache_stats = {
                "cache_hits": d["hits"],
                "cache_hit_rate": self.cache.hit_rate,
                "cache_resident_pages": resident,
                "cache_resident_bytes": resident * self.pool.page_bytes,
                "cache_evicted_nodes": d["evicted_nodes"],
            }
        self.step_stats.append({
            "step": len(self.step_stats),
            "decoded": len(out),
            **self._decode_timing,
            "admitted": self.stats["admitted"] - snap["admitted"],
            "preempted": self.stats["preempted"] - snap["preempted"],
            "reclaimed": self.stats["reclaimed"] - snap["reclaimed"],
            "prefill_tokens": (self.stats["prefill_tokens"]
                               - snap["prefill_tokens"]),
            "recompute_tokens": (self.stats["recompute_tokens"]
                                 - snap["recompute_tokens"]),
            **({"spec_proposed": (self.stats["spec_proposed"]
                                  - snap["spec_proposed"]),
                "spec_accepted": (self.stats["spec_accepted"]
                                  - snap["spec_accepted"])}
               if self.spec is not None else {}),
            "waiting": len(self.admission),
            "prefilling": len(self._prefilling),
            "running": len(self._active_rows()),
            "pages_free": self.pool.num_free,
            "occupancy": self.pool.occupancy(),
            **{k: self.stats[k] - snap[k]
               for k in ("cancelled", "timed_out", "failed",
                         "callback_errors", "faults_injected",
                         "dispatch_failures", "dispatch_recoveries")},
            **cache_stats,
        })
        if tm is not None:
            tm.metrics["engine_steps"].inc()
            if self.mesh is not None and self._epoch_features \
                    and self.stats["steps"] > snap["steps"]:
                tm.metrics["merge_bytes"].inc(
                    self._epoch_features["merge_bytes"])
                tm.metrics["merge_rounds"].inc(
                    self._epoch_features["merge_rounds"])
            t = self._decode_timing
            if "dispatch_time" in t:
                tm.observe("dispatch_s", t["dispatch_time"])
            if t.get("profiled"):
                tm.observe("profile_dispatch_s", t["dispatch_time"])
                tm.observe("profile_device_s", t.get("compute_time", 0.0))
                tm.observe("profile_host_s", t.get("host_time", 0.0))
            self._publish_telemetry()
            c_step1 = self.clock()
            tm.observe("step_s", c_step1 - c_step0)
            tm.end(args={"decoded": len(out)})            # "step"
        return out

    def _cache_step_delta(self) -> Dict[str, int]:
        """Advance the rolling cache-stats snapshot and return the
        delta since the previous step — read-and-update is ATOMIC here,
        the single consumer, so lookups recorded by eager between-step
        admissions land in exactly one step row no matter how often
        external readers poll ``step_stats`` or the metrics registry
        (those readers difference their own snapshots instead)."""
        cur = dict(self.cache.stats)
        prev = self._cache_snap
        self._cache_snap = cur
        return {k: cur[k] - prev.get(k, 0) for k in cur}

    def _publish_telemetry(self) -> None:
        """Fold cumulative engine/cache stats into the metrics registry
        (monotone counter deltas) and refresh the gauges.  Runs every
        step and before any metrics export."""
        tm = self.telemetry
        if tm is None:
            return
        tm.sync_counters("engine", self.stats,
                         telemetry_mod.ENGINE_STAT_COUNTERS)
        gauges = {
            "pool_occupancy": self.pool.occupancy(),
            "pool_free_pages": self.pool.num_free,
            "backoff_pages": self._backoff_pages,
            "running": len(self._active_rows()),
            "waiting": len(self.admission),
            "prefilling": len(self._prefilling),
        }
        if self.cache is not None:
            tm.sync_counters("cache", self.cache.stats,
                             telemetry_mod.CACHE_STAT_COUNTERS)
            resident = self.cache.resident_pages()
            gauges.update(cache_hit_rate=self.cache.hit_rate,
                          cache_resident_pages=resident,
                          cache_resident_bytes=resident
                          * self.pool.page_bytes)
        if self.fused:
            gauges["compile_count"] = self.fused_cache_size
        tm.set_gauges(gauges)

    def publish_metrics(self):
        """Public sync point for registry readers (benchmarks, serve):
        returns the up-to-date :class:`~repro.core.metrics
        .MetricsRegistry`, or None when telemetry is off."""
        if self.telemetry is None:
            return None
        self._publish_telemetry()
        return self.telemetry.metrics

    def export_metrics(self, path: str, extra=None) -> None:
        """Sync and write the schema-tagged metrics JSON."""
        if self.telemetry is None:
            raise RuntimeError(
                "export_metrics needs DecodeEngine(telemetry=...)")
        self._publish_telemetry()
        self.telemetry.export_metrics(path, extra=extra)

    def _decode_with_recovery(self) -> Dict[int, Optional[int]]:
        """Dispatch the decode phase under the degradation ladder.

        A recoverable dispatch failure (``ResourceExhausted`` — the
        analogue of XLA's RESOURCE_EXHAUSTED, raised by a backend or the
        fault injector) walks one ladder rung per retry: demote replicas
        -> evict cached nodes -> generic reclaim/preempt -> shrink
        admission.  Each recovery is followed by a full invariant
        self-check; ``max_dispatch_retries`` bounds the walk, after
        which the error propagates (genuinely fatal)."""
        for attempt in range(self.max_dispatch_retries + 1):
            try:
                return self._decode_phase()
            except ResourceExhausted:
                self.stats["dispatch_failures"] += 1
                if (attempt >= self.max_dispatch_retries
                        or not self._recover_dispatch()):
                    raise
                self.stats["dispatch_recoveries"] += 1
                self._plan_dirty = True
                self.check()
        return {}

    def _recover_dispatch(self) -> bool:
        """One rung of the degradation ladder; ``True`` if anything gave."""
        repl = [n for n in self.forest.nodes.values()
                if "replicas" in n.meta]
        if repl:
            self._demote_replicas(max(repl,
                                      key=lambda n: len(n.page_ids)))
            return True
        if self.cache is not None and self._evict_cached(1) > 0:
            return True
        if self._reclaim_one(set(), allow_preempt=True):
            return True
        if self._backoff_pages < self.pool.num_pages:
            self._backoff_pages += max(1, self.pool.num_pages // 16)
            return True
        return False

    def _decode_phase(self) -> Dict[int, Optional[int]]:
        if self.injector is not None:
            spec = self.injector.take("stall")
            if spec is not None:
                # latency fault (a slow mesh shard / host hiccup): the
                # engine just rides it out — streams are unaffected
                self.stats["faults_injected"] += 1
                time.sleep(float(spec.payload) or 0.002)
            spec = self.injector.take("dispatch")
            if spec is not None:
                # raised BEFORE any state mutation, like a backend OOM
                # surfacing at dispatch: the retry re-enters cleanly
                self.stats["faults_injected"] += 1
                raise ResourceExhausted(
                    f"injected dispatch failure at step "
                    f"{len(self.step_stats)}: RESOURCE_EXHAUSTED "
                    f"(simulated)")
        if self.spec is not None:
            return self._decode_phase_spec()
        if self.fused:
            return self._decode_phase_fused()
        return self._decode_phase_eager()

    def _grow_leaf_tail(self, r: int):
        """Ensure the request's leaf has a page slot for its newest
        token, preempting under pressure (``exclude={r}``); returns the
        leaf.  Shared by the normal append path and the speculative
        commit so their growth/eviction behaviour can never diverge."""
        leaf = self.forest.nodes[self.forest.leaf_of[r]]
        if -(-leaf.length // self.page_size) > len(leaf.page_ids):
            got = self._grow_node_pages(leaf, 1, exclude={r})
            if got is None:
                raise MemoryError(
                    f"KV pool exhausted growing request {r}: nothing "
                    f"left to evict (pool smaller than the working set)")
        return leaf

    def _append_pending(self, rows0: List[int]) -> None:
        """Append each running request's pending token to its leaf and
        grow tail pages, preempting the fewest-generated victim when the
        pool runs dry.  Device pendings (fused async path) are appended
        as placeholders and patched at the next ``flush_tokens``."""
        for r in rows0:
            req = self.requests[r]
            if req.state != RUNNING:   # evicted growing an earlier row
                continue
            if req.pending is None:    # dispatch-retry re-entry: this
                continue               # row already appended this step
            if req.pending is PENDING_DEVICE:
                ent, row = self._pending_ref.pop(r)
                self.forest.append_token(r, _PLACEHOLDER)
                leaf = self.forest.nodes[self.forest.leaf_of[r]]
                ent.patches.append((r, row, len(req.generated), leaf.id,
                                    len(leaf.tokens) - 1))
                req.generated.append(_PLACEHOLDER)
            else:
                self.forest.append_token(r, req.pending)
                req.generated.append(req.pending)
                if self.telemetry is not None:
                    self._note_token(req)
            req.pending = None
            try:
                self._grow_leaf_tail(r)
            except MemoryError:
                # nothing reclaimable right now.  With other tenants the
                # pressure is transient: preempt-and-recompute keeps the
                # greedy stream byte-identical.  A lone request can never
                # get more room — fail it instead of livelocking.
                others = [q for q in self.requests.values()
                          if q.rid != r
                          and q.state in (WAITING, PREFILL, RUNNING)]
                if others:
                    self._preempt(r)
                else:
                    self._fail_request(r, "kv_exhausted", flush=False)

    def _decode_phase_eager(self) -> Dict[int, int]:
        cfg = self.cfg
        rows0 = self._active_rows()
        if not rows0:
            return {}
        t0 = time.perf_counter()
        c0 = self.clock() if self.telemetry is not None else 0.0
        flush_before = self._decode_timing.get("flush_time", 0.0)
        # 1. append pending tokens to leaves (may evict under pressure)
        self._append_pending(rows0)
        rows = self._active_rows()
        if not rows:
            return {}
        tokens = [self.requests[r].generated[-1] for r in rows]

        # 2. plan lifecycle: rebuild exactly when the plan key changed
        #    (membership, path structure, tail page) or on the interval
        if (self.replan_interval is not None
                and self._steps_since_plan >= self.replan_interval):
            self._plan_dirty = True
        if (self._plan_dirty
                or plan_mod.plan_key(self.forest, rows) != self._plan_key):
            self._rebuild_plans()
        else:
            self._advance_qpos()
        self._steps_since_plan += 1

        B = len(rows)
        ctx = np.array([self.forest.context_len(r) for r in rows], np.int32)
        q_pos = jnp.asarray(ctx - 1)
        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None].T,
                     q_pos[:, None])                       # (B,1,d)

        # tail page info, converted host->device ONCE per step (not once
        # per attention layer)
        tail_pages, tail_base, tail_off = [], [], []
        for i, r in enumerate(rows):
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tp = (leaf.length - 1) // self.page_size
            tail_pages.append(leaf.page_ids[tp])
            tail_base.append(leaf.start_pos + tp * self.page_size)
            tail_off.append((leaf.length - 1) % self.page_size)
        tail_pages = jnp.asarray(np.asarray(tail_pages), jnp.int32)
        tail_base = jnp.asarray(np.asarray(tail_base), jnp.int32)
        tail_off = jnp.asarray(np.asarray(tail_off), jnp.int32)

        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                self.pool.write_tokens(la, tail_pages, tail_off,
                                       k_new[:, 0], v_new[:, 0])
                k_pool, v_pool = self.pool.layer_pools(la)
                qb = q[:, 0]                                # (B, h, hd)
                o = self._attend(qb, k_pool, v_pool, window, B,
                                 tail_pages, tail_base, q_pos)
                y = L.dense(p["attn"]["wo"],
                            o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            elif kind.mixer == "mamba":
                states = self.mamba_state[j]
                conv = jnp.concatenate([states[r][0] for r in rows], 0)
                ssm = jnp.concatenate([states[r][1] for r in rows], 0)
                y, (conv_n, ssm_n) = M.mamba_decode(p["mamba"], cfg, h,
                                                    conv, ssm)
                for i, r in enumerate(rows):
                    states[r] = (conv_n[i:i + 1], ssm_n[i:i + 1])
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)

        logits = T._unembed(self.params, cfg, x)[:, 0]      # (B, V)
        if self.injector is not None:
            spec = self.injector.take("nan_logits")
            if spec is not None:
                target = spec.rid if spec.rid in rows else rows[0]
                logits = logits.at[rows.index(target)].set(jnp.nan)
                self.stats["faults_injected"] += 1
        self.key, sk = jax.random.split(self.key)
        toks_dev = sampler.sample(logits, sk, self.temperature)
        t1 = time.perf_counter()
        # dispatch is done; the timer must cover the actual compute too
        toks = np.asarray(jax.block_until_ready(toks_dev))
        t2 = time.perf_counter()
        bad_rows: List[int] = []
        if self.nan_guard:
            # the eager path syncs every step anyway, so a host-side
            # finite check costs one extra small transfer
            okv = np.asarray(jnp.isfinite(logits).all(-1))
            bad_rows = [r for i, r in enumerate(rows) if not okv[i]]
        out = {}
        for i, r in enumerate(rows):
            if r in bad_rows:
                continue
            req = self.requests[r]
            req.pending = int(toks[i])
            req.computed_hwm = max(req.computed_hwm, int(ctx[i]))
            out[r] = int(toks[i])
            if len(req.generated) >= req.max_new:
                req.state = DONE
        for r in bad_rows:
            # quarantine: the poisoned token never enters the stream,
            # the batch keeps decoding without the failed row
            self.stats["nan_rows"] += 1
            self._fail_request(r, "nan_logits", flush=False)
        self.stats["steps"] += 1
        # any flush that ran inside this phase (preempting appends) has
        # billed flush_time already; keep it out of the dispatch split
        flush_in = self._decode_timing.get("flush_time", 0.0) \
            - flush_before
        self._decode_timing.update(
            dispatch_time=max(0.0, t1 - t0 - flush_in),
            compute_time=t2 - t1)
        self.stats["decode_dispatch_time"] += \
            self._decode_timing["dispatch_time"]
        self.stats["decode_time"] += time.perf_counter() - t0
        if self.telemetry is not None:
            self.telemetry.complete("decode", c0, self.clock(),
                                    args={"mode": "eager",
                                          "rows": len(rows)})
        return out

    def _attend(self, qb, k_pool, v_pool, window, B,
                tail_pages, tail_base, q_pos):
        plan, prepared = self._plans[window]
        # frozen part: backend partials over all full pages
        o_f, m_f, l_f = self._backend.partials(
            qb, k_pool, v_pool, plan, prepared, window=window)
        # tail part: each request's growing last page
        kt = k_pool[tail_pages]
        vt = v_pool[tail_pages]
        o_t, m_t, l_t = ops.single_page_attention(
            qb, kt, vt, tail_base, q_pos, window=window)
        o, _, _ = ref_mod.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
        return o.astype(qb.dtype)

    # ------------------------------------------------------------------ #
    # fused decode phase: one jitted, donated, bucketed dispatch per
    # token; host syncs only at plan-rebuild/admission/eviction/
    # completion boundaries (serving/step_fn.py, DESIGN.md §8)
    # ------------------------------------------------------------------ #
    def _decode_phase_fused(self) -> Dict[int, Optional[int]]:
        rows0 = self._active_rows()
        if not rows0:
            return {}
        t0 = time.perf_counter()
        tm = self.telemetry
        c0 = self.clock() if tm is not None else 0.0
        flush_before = self._decode_timing.get("flush_time", 0.0)
        # sampled profiling (telemetry.profile_every): this step blocks
        # on the device to split dispatch/device/host phases; unsampled
        # steps stay on the async fast path untouched
        profiled = tm is not None \
            and tm.should_profile(len(self.step_stats))
        # 1. append pending tokens (host ints after a sync / prefill,
        #    otherwise the in-flight device array via placeholders)
        self._append_pending(rows0)
        rows = self._active_rows()
        if not rows:
            return {}

        # 2. plan lifecycle: a rebuild is the sync point — deferred
        #    tokens land, batched SSM state scatters back, plans/base
        #    arrays are rebuilt bucketed
        if (self.replan_interval is not None
                and self._steps_since_plan >= self.replan_interval):
            self._plan_dirty = True
        if (self._plan_dirty or self._fused_rows != rows
                or plan_mod.plan_key(self.forest, rows) != self._plan_key):
            self._fused_epoch(rows)
        else:
            self._fused_delta += 1
        self._steps_since_plan += 1

        # 3. input tokens: in steady state the previous dispatch's device
        #    array (no host round-trip); after any sync, host values
        if (not self._flushed_since_dispatch and self._last_out is not None
                and self._last_out[0] == rows):
            tok_in = self._last_out[1]
        else:
            tok = np.zeros(self._fused_bucket, np.int32)
            tok[:len(rows)] = [self.requests[r].generated[-1] for r in rows]
            tok_in = jnp.asarray(tok)
            if self._replicated_sharding is not None:
                tok_in = jax.device_put(tok_in, self._replicated_sharding)

        # injected NaN: corrupt one KV slot of the target's PRIVATE leaf
        # so the dispatch's attention reads it, poisons that row's
        # logits, and the row_ok flag catches it at the next flush —
        # exercising the real corruption path, not a shortcut
        if self.injector is not None:
            spec = self.injector.take("nan_logits")
            if spec is not None:
                target = spec.rid if spec.rid in rows else rows[0]
                leaf = self.forest.nodes[self.forest.leaf_of[target]]
                if (leaf.length >= 2 and len(leaf.requests) == 1
                        and not leaf.children):
                    slot = leaf.length - 2
                    page = leaf.page_ids[slot // self.page_size]
                    off = slot % self.page_size
                    self.pool.k = self.pool.k.at[:, page, off].set(
                        jnp.nan)
                    self._nan_dirty.append((page, off))
                    self.stats["faults_injected"] += 1
                else:       # leaf shared or too short: try again later
                    self.injector.requeue(spec)

        # 4. single dispatch: layers + KV writes + attention + merge +
        #    FFN + unembed + sampling, pool/SSM state donated
        conv_all, ssm_all = self._mamba_carry
        state = step_fn_mod.StepState(self.pool.k, self.pool.v,
                                      conv_all, ssm_all)
        t_d0 = time.perf_counter()
        if self.mesh is not None:
            # the sharded step fn has no row_ok output (nan_guard is
            # rejected with a mesh at construction)
            toks_dev, self.key, state = self._step_fn(
                self.params, state, tok_in, self.key, self._fused_base,
                np.int32(self._fused_delta), self._fused_prepared)
            ok_dev = None
        else:
            toks_dev, ok_dev, self.key, state = self._step_fn(
                self.params, state, tok_in, self.key, self._fused_base,
                np.int32(self._fused_delta), self._fused_prepared)
        t_d1 = time.perf_counter()
        calibrating = self.calibrate and self.mesh is not None
        if calibrating or profiled:
            # calibration/profiling fit against TRUE step seconds, so
            # the async dispatch must block here (costs the overlap;
            # opt-in — calibrate blocks every step, profile_every only
            # the sampled ones)
            jax.block_until_ready(toks_dev)
        t_d2 = time.perf_counter()
        # calibrate keeps its historical meaning: dispatch_time is the
        # full blocked step.  Profiled steps split submit vs device.
        dispatch = (t_d2 if calibrating else t_d1) - t_d0
        self.pool.k, self.pool.v = state.pool_k, state.pool_v
        self._mamba_carry = (state.conv, state.ssm)
        ent = _Deferred(toks_dev, list(rows),
                        ok=ok_dev if self.nan_guard else None)
        self._deferred.append(ent)
        self._last_out = (list(rows), toks_dev)
        self._flushed_since_dispatch = False
        out: Dict[int, Optional[int]] = {}
        done_any = False
        for i, r in enumerate(rows):
            req = self.requests[r]
            req.pending = PENDING_DEVICE
            self._pending_ref[r] = (ent, i)
            req.computed_hwm = max(req.computed_hwm,
                                   self.forest.context_len(r))
            out[r] = None
            if len(req.generated) >= req.max_new:
                req.state = DONE
                done_any = True
        self.stats["steps"] += 1
        self.stats["fused_calls"] += 1
        self.stats["decode_dispatch_time"] += dispatch
        flush_in = self._decode_timing.get("flush_time", 0.0) \
            - flush_before
        self._decode_timing.update(dispatch_time=dispatch)
        if profiled and not calibrating:
            self._decode_timing.update(
                compute_time=t_d2 - t_d1, profiled=True,
                host_time=max(0.0, t_d0 - t0 - flush_in))
        if self.mesh is not None and self._epoch_features:
            self._decode_timing.update(self._epoch_features)
            if profiled and self._epoch_shard_features:
                # per-shard attribution of the sampled step (feeds
                # CostModel.fit / imbalance analysis downstream)
                self._decode_timing.update(self._epoch_shard_features)
        if done_any:
            # completion boundary: finished streams must be readable
            self.flush_tokens()
            for r in rows:
                if self.requests[r].done:
                    out[r] = self.requests[r].generated[-1]
        self.stats["decode_time"] += time.perf_counter() - t0
        if tm is not None:
            tm.complete("decode", c0, self.clock(),
                        args={"mode": "fused", "rows": len(rows),
                              "profiled": bool(profiled)})
        return out

    def _fused_epoch(self, rows: List[int]) -> None:
        """Start a new plan epoch (the fused path's only sync point)."""
        self.flush_tokens()
        self._sync_mamba_state()
        t0 = time.perf_counter()
        c0 = self.clock() if self.telemetry is not None else 0.0
        B = len(rows)
        bucket = plan_mod.bucket_pow2(B)
        req_rows = {r: i for i, r in enumerate(rows)}
        ps = self.page_size
        truncate = {}
        for r in rows:
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            truncate[leaf.id] = max(0, ((leaf.length - 1) // ps) * ps)
        if self.mesh is not None:
            self._sharded_epoch(rows, bucket, req_rows, truncate)
        else:
            build = (plan_mod.flash_plan
                     if self._backend.plan_kind == "flash"
                     else plan_mod.build_plan)
            prepared = []
            sig: List = [bucket]
            for w in self._windows():
                p = build(self.forest, self.cost_model, self.num_lanes,
                          self.max_q, self.max_kv_per_task,
                          req_rows=req_rows, window=w, truncate=truncate)
                p = plan_mod.bucket_plan(p, bucket)
                pr = self._backend.prepare(p)
                prepared.append(pr)
                sig.append((w,) + tuple(tuple(a.shape)
                                        for a in jax.tree.leaves(pr)))
            self._fused_prepared = tuple(prepared)
            self.bucket_signatures.add(tuple(sig))

            valid = np.zeros(bucket, bool)
            valid[:B] = True
            q_pos0 = np.full(bucket, -1, np.int32)
            tail_page = np.full(bucket, self.pool.trash_page, np.int32)
            tail_base = np.zeros(bucket, np.int32)
            tail_off0 = np.zeros(bucket, np.int32)
            for i, r in enumerate(rows):
                q_pos0[i] = self.forest.context_len(r) - 1
                leaf = self.forest.nodes[self.forest.leaf_of[r]]
                tp = (leaf.length - 1) // ps
                tail_page[i] = leaf.page_ids[tp]
                tail_base[i] = leaf.start_pos + tp * ps
                tail_off0[i] = (leaf.length - 1) % ps
            self._fused_base = step_fn_mod.StepBase(
                jnp.asarray(valid), jnp.asarray(q_pos0),
                jnp.asarray(tail_page), jnp.asarray(tail_base),
                jnp.asarray(tail_off0))
        self._fused_rows = list(rows)
        self._fused_bucket = bucket
        self._fused_delta = 0
        self._gather_mamba_state(rows, bucket)
        self._plan_key = plan_mod.plan_key(self.forest, rows)
        self._plan_dirty = False
        self._steps_since_plan = 0
        self.stats["replans"] += 1
        self.stats["plan_time"] += time.perf_counter() - t0
        if self.telemetry is not None:
            c1 = self.clock()
            self.telemetry.complete("plan_build", c0, c1,
                                    args={"rows": len(rows),
                                          "bucket": self._fused_bucket})
            self.telemetry.observe("plan_build_s", c1 - c0)

    def _sharded_epoch(self, rows: List[int], bucket: int,
                       req_rows: Dict[int, int],
                       truncate: Dict[int, int]) -> None:
        """Mesh-mode epoch: per-shard plans + stacked SPMD step inputs.

        One ``DecodePlan`` per data shard (subtasks forced to the shard
        holding their pages, sequence splits cut at shard boundaries —
        ``core.plan.build_sharded_plan``), all bucketed to COMMON shapes
        so the prepared arrays stack into ``(D, ...)`` inputs; the tail
        layout becomes per-shard local page rows (non-owners point at
        their shard's trash page).
        """
        from ..distributed import step_fn as sharded_step_fn_mod
        B = len(rows)
        ps = self.page_size
        D = self.pool.num_shards
        stride = self.pool.page_stride
        if self.replicate:
            self._replication_sweep(rows)
        if self.calibrate:
            # refit hardware coefficients from the measured steps so the
            # plans built below divide/balance/replicate on real costs
            self.recalibrate()
        self.pool.canonicalize()
        prepared = []
        sig: List = [("mesh", D, self.mesh.shape["model"], bucket)]
        self._sharded_plans = {}
        for w in self._windows():
            sp = plan_mod.build_sharded_plan(
                self.forest, self.cost_model, D, stride,
                self.num_lanes, self.max_q, self.max_kv_per_task,
                req_rows=req_rows, window=w, truncate=truncate,
                num_rows=bucket)
            self._sharded_plans[w] = sp
            shard_pr = [self._backend.prepare(p) for p in sp.shards]
            pr = jax.tree.map(lambda *xs: jnp.stack(xs), *shard_pr)
            prepared.append(pr)
            sig.append((w,) + tuple(tuple(a.shape)
                                    for a in jax.tree.leaves(pr)))
        self._fused_prepared = tuple(prepared)

        # sparse-merge bookkeeping: which rows must cross the wire, and
        # which shards hold a shard-local contribution to them (all
        # windows OR together — one contrib vector serves every layer)
        row_sh = np.zeros((D, bucket), bool)
        merge_mask = np.zeros(bucket, bool)
        rep_set: Set[int] = set()
        for sp in self._sharded_plans.values():
            if sp.row_shards is not None:
                row_sh |= sp.row_shards
            if sp.merge_rows is not None:
                merge_mask |= sp.merge_rows
            rep_set |= sp.replicated or set()

        valid = np.zeros(bucket, bool)
        valid[:B] = True
        q_pos0 = np.full(bucket, -1, np.int32)
        tail_page = np.full((D, bucket), self.pool.local_trash, np.int32)
        tail_owner = np.zeros((D, bucket), bool)
        tail_base = np.zeros(bucket, np.int32)
        tail_off0 = np.zeros(bucket, np.int32)
        for i, r in enumerate(rows):
            q_pos0[i] = self.forest.context_len(r) - 1
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tp = (leaf.length - 1) // ps
            reps = leaf.meta.get("replicas")
            if reps is not None:
                # every shard writes the row's new KV into its OWN
                # replica tail page, keeping replicas bitwise in sync;
                # ownership (whose tail partial counts) depends on
                # whether the row merges: fully-replicated rows own
                # everywhere (identical results), merge rows own only on
                # the primary (one contribution on the wire)
                for sh in range(D):
                    g = reps[sh][tp]
                    tail_page[sh, i] = self.pool.local_of(g)
                if leaf.id in rep_set:
                    tail_owner[:, i] = True
                else:
                    sh = self.pool.shard_of(leaf.page_ids[tp])
                    tail_owner[sh, i] = True
                    row_sh[sh, i] = True
            else:
                g = leaf.page_ids[tp]
                sh = self.pool.shard_of(g)
                tail_page[sh, i] = self.pool.local_of(g)
                tail_owner[sh, i] = True
                row_sh[sh, i] = True
            tail_base[i] = leaf.start_pos + tp * ps
            tail_off0[i] = (leaf.length - 1) % ps

        # packed gather/scatter for the sparse subgroup merge: Bm is part
        # of the compiled signature (bucketed pow2; 0 drops the
        # collective), the mask VALUES are not — one program per shape
        mrows = np.nonzero(merge_mask)[0]
        Bm = plan_mod.bucket_pow2(len(mrows)) if len(mrows) else 0
        gather = np.zeros(Bm, np.int32)
        scatter = np.full(Bm, bucket, np.int32)    # pad -> drop
        gather[:len(mrows)] = mrows
        scatter[:len(mrows)] = mrows
        contrib = (row_sh[:, merge_mask].any(axis=1) if Bm
                   else np.zeros(D, bool))
        sig.append(("merge", Bm))
        self.bucket_signatures.add(tuple(sig))
        self._fused_base = sharded_step_fn_mod.ShardedStepBase(
            jnp.asarray(valid), jnp.asarray(q_pos0),
            jnp.asarray(tail_page), jnp.asarray(tail_base),
            jnp.asarray(tail_off0), jnp.asarray(tail_owner),
            jnp.asarray(gather), jnp.asarray(scatter),
            jnp.asarray(contrib))
        self._record_epoch_features(Bm)

    def _record_epoch_features(self, merge_bucket: int) -> None:
        """Per-step cost-model features of the new epoch, attached to
        every step_stats row until the next epoch (``recalibrate`` fits
        hardware coefficients against them).  Compute terms take the
        heaviest shard's totals over its parallel lanes — the same
        makespan proxy the scheduler optimises."""
        ps = self.page_size
        lanes = max(self.num_lanes, 1)
        n_attn_w = {w: 0 for w in self._windows()}
        for kind, _ in self.layers:
            if kind.mixer in ("attn", "attn_local"):
                w = (self.cfg.sliding_window if kind.mixer == "attn_local"
                     else 0)
                n_attn_w[w] += 1
        hbm = steps = 0.0
        shard_hbm: List[float] = []
        shard_steps: List[float] = []
        for w, sp in self._sharded_plans.items():
            per_shard = [sum(self.cost_model.hbm_bytes(s.n_q, s.n)
                             for s in p.subtasks) for p in sp.shards]
            per_steps = [sum(max(1, -(-s.n // ps)) for s in p.subtasks)
                         for p in sp.shards]
            if not per_shard:
                continue
            if not shard_hbm:
                shard_hbm = [0.0] * len(per_shard)
                shard_steps = [0.0] * len(per_steps)
            for i, (b, g) in enumerate(zip(per_shard, per_steps)):
                shard_hbm[i] += n_attn_w[w] * b / lanes
                shard_steps[i] += n_attn_w[w] * g / lanes
            k = int(np.argmax(per_shard))
            hbm += n_attn_w[w] * per_shard[k] / lanes
            steps += n_attn_w[w] * per_steps[k] / lanes
        D = self.pool.num_shards
        rounds = (int(np.ceil(np.log2(D)))
                  if D > 1 and merge_bucket > 0 else 0)
        n_attn = sum(n_attn_w.values())
        wire = (merge_bucket * self.cfg.num_heads
                * (self.cfg.head_dim + 2) * 4)
        self._epoch_features = {
            "hbm_bytes": hbm, "grid_steps": steps,
            "merge_bytes": n_attn * rounds * wire,
            "merge_rounds": n_attn * rounds,
        }
        # per-shard vectors kept separately: attached to profiled rows
        # only (they would bloat every ordinary step row)
        self._epoch_shard_features = {
            "shard_hbm_bytes": shard_hbm,
            "shard_grid_steps": shard_steps,
        } if shard_hbm else {}

    def predicted_step_seconds(self, hw=None) -> float:
        """Model-predicted per-step attention + merge seconds for the
        current epoch on a real mesh: the heaviest shard's HBM/grid time
        plus the cross-shard merge wire/launch terms, under ``hw`` (by
        default the current, possibly :meth:`recalibrate`-fitted,
        hardware coefficients — pass a fixed :class:`HardwareSpec` when
        comparing across engines).  Excludes the dense
        (FFN/unembed/dispatch) base cost, which is
        device-count-independent — callers compare or offset it against
        a measured single-device step."""
        f = self._epoch_features
        if not f:
            return 0.0
        hw = hw or self.cost_model.hw
        return (f["hbm_bytes"] / hw.hbm_bw
                + f["grid_steps"] * hw.grid_step_overhead
                + f["merge_bytes"] / hw.ici_bw
                + f["merge_rounds"] * hw.launch_overhead)

    def _sync_mamba_state(self) -> None:
        """Scatter the batched device SSM state back into the per-request
        store (device slices — no host transfer)."""
        if self._mamba_carry is None or self._fused_rows is None:
            return
        conv_all, ssm_all = self._mamba_carry
        for li, j in enumerate(self._mamba_layer_js):
            st = self.mamba_state.setdefault(j, {})
            for i, r in enumerate(self._fused_rows):
                req = self.requests.get(r)
                if req is not None and req.state == RUNNING:
                    st[r] = (conv_all[li, i:i + 1], ssm_all[li, i:i + 1])

    def _gather_mamba_state(self, rows: List[int], bucket: int) -> None:
        """Stack per-request SSM state into per-layer batched device
        arrays for the new epoch (padded rows stay zero)."""
        js = self._mamba_layer_js
        cfg = self.cfg
        K, conv_dim = cfg.ssm_conv, cfg.d_inner + 2 * cfg.ssm_state
        conv = jnp.zeros((len(js), bucket, max(K - 1, 0), conv_dim),
                         jnp.float32)
        ssm = jnp.zeros((len(js), bucket, max(cfg.ssm_heads, 1),
                         max(cfg.ssm_head_dim, 1), max(cfg.ssm_state, 1)),
                        jnp.float32)
        for li, j in enumerate(js):
            st = self.mamba_state.get(j, {})
            conv = conv.at[li, :len(rows)].set(
                jnp.concatenate([st[r][0] for r in rows], 0))
            ssm = ssm.at[li, :len(rows)].set(
                jnp.concatenate([st[r][1] for r in rows], 0))
        if self._replicated_sharding is not None:
            conv = jax.device_put(conv, self._replicated_sharding)
            ssm = jax.device_put(ssm, self._replicated_sharding)
        self._mamba_carry = (conv, ssm)

    # ------------------------------------------------------------------ #
    # speculative tree-decoding phase (serving/speculation.py, DESIGN §10):
    # draft-propose -> tree-verify (one multi-query dispatch) ->
    # accept/commit (KV moves from draft pages to the leaf tail) ->
    # rollback (draft pages released)
    # ------------------------------------------------------------------ #
    def _rollback_drafts(self, rid: int) -> None:
        """Release a request's live draft tree: detach the virtual
        branch-head queries, prune the draft nodes leaf-first, and
        return their pages to the allocator.  Idempotent no-op when the
        request holds no drafts (the common non-speculative case)."""
        st = self._drafts.pop(rid, None)
        if st is None:
            return
        for virt in st.virts:
            if virt in self.forest.leaf_of:
                self.forest.detach_request(virt)
        for nid in reversed(st.nodes):      # children before parents
            if nid not in self.forest.nodes:
                continue
            pages = self.forest.prune_leaf(nid)
            if pages:
                self.pool.allocator.release(pages)

    def _grow_drafts(self, rows: List[int]) -> None:
        """Propose and materialise each running request's draft tree.

        Draft pages are allocated best-effort: speculation never evicts
        to make room (a wrong guess would have paid an eviction for
        nothing), it just drafts fewer nodes — committed-token progress
        is unaffected because verification degenerates to normal decode.
        """
        reserve = self.policy.reserve_pages
        for r in rows:
            req = self.requests[r]
            room = req.max_new - len(req.generated)
            if room <= 0:        # only the done-transition dispatch left
                continue
            branches = self.proposer.propose(req.seq, max_tokens=room)
            if not branches:
                continue
            leaf_id = self.forest.leaf_of[r]
            st = spec_mod.DraftState(r)
            stalled = False
            for chain in branches:
                parent = leaf_id
                for tok in chain:
                    if self.pool.num_free - reserve < 1:
                        stalled = True
                        break
                    node = self.forest.add_draft(parent, int(tok))
                    node.page_ids = self.pool.allocator.alloc(
                        1, hint=node.id)
                    virt = self._next_virt
                    self._next_virt -= 1
                    self.forest.attach_request(virt, node.id)
                    st.nodes.append(node.id)
                    st.virts.append(virt)
                    parent = node.id
                if stalled:
                    break
            if stalled:
                self.stats["spec_draft_stalls"] += 1
            if st.nodes:
                self._drafts[r] = st
                self.stats["spec_proposed"] += len(st.nodes)

    def _spec_layout(self, rows: List[int]):
        """Stack the verification queries: per request its committed-tail
        base query (the normal decode position) then one query per draft
        node, each with its token, absolute position, and the KV slot
        the dispatch writes that token's K/V into."""
        ps = self.page_size
        tokens: List[int] = []
        q_pos: List[int] = []
        w_page: List[int] = []
        w_off: List[int] = []
        req_rows: Dict[int, int] = {}
        head_rows: Dict[int, Dict[int, int]] = {}
        for r in rows:
            req = self.requests[r]
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tp = (leaf.length - 1) // ps
            head_rows[r] = {leaf.id: len(tokens)}
            req_rows[r] = len(tokens)
            tokens.append(req.generated[-1])
            q_pos.append(leaf.end_pos - 1)
            w_page.append(leaf.page_ids[tp])
            w_off.append((leaf.length - 1) % ps)
            st = self._drafts.get(r)
            if st is None:
                continue
            for nid, virt in zip(st.nodes, st.virts):
                node = self.forest.nodes[nid]
                head_rows[r][nid] = len(tokens)
                req_rows[virt] = len(tokens)
                tokens.append(int(node.tokens[0]))
                q_pos.append(node.end_pos - 1)
                w_page.append(node.page_ids[0])
                w_off.append(0)
        return tokens, q_pos, w_page, w_off, req_rows, head_rows

    def _decode_phase_spec(self) -> Dict[int, Optional[int]]:
        rows0 = self._active_rows()
        if not rows0:
            return {}
        t0 = time.perf_counter()
        tm = self.telemetry
        self._append_pending(rows0)        # host ints: spec never defers
        rows = self._active_rows()
        if not rows:
            return {}
        c0 = self.clock() if tm is not None else 0.0
        self._grow_drafts(rows)
        if tm is not None:
            tm.complete("spec_propose", c0, self.clock(),
                        args={"rows": len(rows),
                              "drafts": sum(len(st.nodes) for st in
                                            self._drafts.values())})
        # injected NaN: poison a committed KV slot of the target's leaf
        # (as in the fused path) so every verify row of that request —
        # base query and draft heads — reads it through the verify plan
        if self.injector is not None:
            spec = self.injector.take("nan_logits")
            if spec is not None:
                target = spec.rid if spec.rid in rows else rows[0]
                leaf = self.forest.nodes[self.forest.leaf_of[target]]
                owners = [q for q in leaf.requests if q >= 0]
                kids = [c for c in leaf.children
                        if not self.forest.nodes[c].meta.get("draft")]
                if leaf.length >= 2 and owners == [target] and not kids:
                    slot = leaf.length - 2
                    page = leaf.page_ids[slot // self.page_size]
                    off = slot % self.page_size
                    self.pool.k = self.pool.k.at[:, page, off].set(
                        jnp.nan)
                    self._nan_dirty.append((page, off))
                    self.stats["faults_injected"] += 1
                else:
                    self.injector.requeue(spec)
        tokens, q_pos, w_page, w_off, req_rows, head_rows = \
            self._spec_layout(rows)
        tp0 = time.perf_counter()
        plans = {}
        for w in self._windows():
            p = plan_mod.build_verify_plan(
                self.forest, self.cost_model, req_rows, self.num_lanes,
                self.max_q, self.max_kv_per_task, window=w,
                kind=self._backend.plan_kind)
            plans[w] = p
        self.stats["replans"] += 1
        self.stats["plan_time"] += time.perf_counter() - tp0
        t_d0 = time.perf_counter()
        c_v0 = self.clock() if tm is not None else 0.0
        if self._spec_step_fn is not None:
            toks, ok = self._spec_verify_fused(tokens, q_pos, w_page,
                                               w_off, plans)
        else:
            toks, ok = self._spec_verify_eager(tokens, q_pos, w_page,
                                               w_off, plans)
        t_d1 = time.perf_counter()
        if tm is not None:
            tm.complete("spec_verify", c_v0, self.clock(),
                        args={"queries": len(tokens)})
        if self.nan_guard:
            # quarantine before commit: a poisoned request's drafts roll
            # back with it and nothing enters its committed stream
            for r in list(rows):
                if not bool(ok[req_rows[r]]):
                    self.stats["nan_rows"] += 1
                    self._fail_request(r, "nan_logits", flush=False)
        c_a0 = self.clock() if tm is not None else 0.0
        out = self._spec_commit(rows, toks, head_rows)
        self.stats["steps"] += 1
        self.stats["spec_steps"] += 1
        self._decode_timing.update(
            dispatch_time=t_d1 - t_d0,
            compute_time=time.perf_counter() - t_d1)
        self.stats["decode_dispatch_time"] += t_d1 - t_d0
        self.stats["decode_time"] += time.perf_counter() - t0
        if tm is not None:
            tm.complete("spec_accept", c_a0, self.clock(),
                        args={"accepted": sum(
                            1 for v in out.values() if v is not None)})
        return out

    def _spec_verify_eager(self, tokens, q_pos, w_page, w_off,
                           plans) -> np.ndarray:
        """Eager multi-query verification: per-layer loop, the backend's
        ``partials`` over the verify plan (which covers the whole forest,
        so no tail/POR merge), greedy argmax on the host."""
        cfg = self.cfg
        B = len(tokens)
        qp = jnp.asarray(np.asarray(q_pos, np.int32))
        pages = np.asarray(w_page)
        offs = np.asarray(w_off)
        prepared = {w: self._backend.prepare(p) for w, p in plans.items()}
        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None].T,
                     qp[:, None])                            # (B,1,d)
        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 qp[:, None])
                self.pool.write_tokens(la, pages, offs,
                                       k_new[:, 0], v_new[:, 0])
                k_pool, v_pool = self.pool.layer_pools(la)
                o, _, _ = self._backend.partials(
                    q[:, 0], k_pool, v_pool, plans[window],
                    prepared[window], window=window)
                y = L.dense(p["attn"]["wo"],
                            o.astype(q.dtype).reshape(
                                B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)
        logits = T._unembed(self.params, cfg, x)[:, 0]       # (B, V)
        return (np.asarray(jnp.argmax(logits, -1)),
                np.asarray(jnp.isfinite(logits).all(-1)))

    def _spec_verify_fused(self, tokens, q_pos, w_page, w_off,
                           plans) -> np.ndarray:
        """Fused verification: ONE jitted, donated, bucketed dispatch
        scores every branch head (serving/step_fn.make_spec_step_fn);
        the host syncs once per verify step for the acceptance walk."""
        B = len(tokens)
        bucket = plan_mod.bucket_pow2(B)
        prepared = []
        sig: List = [("spec", bucket)]
        for w in self._windows():
            p = plan_mod.bucket_plan(plans[w], bucket)
            pr = self._backend.prepare(p)
            prepared.append(pr)
            sig.append((w,) + tuple(tuple(a.shape)
                                    for a in jax.tree.leaves(pr)))
        self.bucket_signatures.add(tuple(sig))
        tok = np.zeros(bucket, np.int32)
        tok[:B] = tokens
        qp = np.full(bucket, -1, np.int32)
        qp[:B] = q_pos
        wp = np.full(bucket, self.pool.trash_page, np.int32)
        wp[:B] = w_page
        wo = np.zeros(bucket, np.int32)
        wo[:B] = w_off
        state = step_fn_mod.SpecState(self.pool.k, self.pool.v)
        toks_dev, ok_dev, state = self._spec_step_fn(
            self.params, state, jnp.asarray(tok), jnp.asarray(qp),
            jnp.asarray(wp), jnp.asarray(wo), tuple(prepared))
        self.pool.k, self.pool.v = state.pool_k, state.pool_v
        self.stats["fused_calls"] += 1
        return np.asarray(toks_dev)[:B], np.asarray(ok_dev)[:B]

    def _spec_commit(self, rows: List[int], toks: np.ndarray,
                     head_rows) -> Dict[int, Optional[int]]:
        """Greedy accept/commit/rollback for every request.

        Per request: walk the scored draft tree (``speculation.
        accept_walk``), roll the whole tree back (freeing its pages),
        then append the accepted tokens to the committed leaf — moving
        each one's KV from its draft page to the leaf's tail slot in a
        single aliasing-safe ``copy_slots`` gather/scatter — and carry
        the correction/bonus token as the next ``pending``.  The
        committed forest layout after a speculative step is exactly
        what non-speculative decode would have produced, so plans,
        eviction, and the differential harness see nothing new.
        """
        ps = self.page_size
        out: Dict[int, Optional[int]] = {}
        for r in rows:
            req = self.requests[r]
            if req.state != RUNNING:   # preempted committing earlier rows
                continue
            leaf_id = self.forest.leaf_of[r]
            rowmap = head_rows[r]
            room = req.max_new - len(req.generated)
            accepted, final_tok = spec_mod.accept_walk(
                self.forest, leaf_id,
                lambda nid: toks[rowmap[nid]], room)
            # source KV slots + token values, recorded before rollback
            moves = [(self.forest.nodes[nid].page_ids[0],
                      int(self.forest.nodes[nid].tokens[0]))
                     for nid in accepted]
            self._rollback_drafts(r)
            copies = []
            for src_page, tok in moves:
                self.forest.append_token(r, tok)
                req.generated.append(tok)
                leaf = self._grow_leaf_tail(r)
                # exclude={r} forbids self-preemption, so r must still be
                # running; a silent skip here would leave the appended
                # tokens without their KV copy
                assert req.state == RUNNING, (r, req.state)
                tp = (leaf.length - 1) // ps
                copies.append((src_page, 0, leaf.page_ids[tp],
                               (leaf.length - 1) % ps))
            if copies:
                src_p, src_o, dst_p, dst_o = map(np.asarray, zip(*copies))
                self.pool.copy_slots(src_p, src_o, dst_p, dst_o)
                self.stats["spec_accepted"] += len(copies)
                if self.telemetry is not None:
                    self._note_token(req)
            req.computed_hwm = max(req.computed_hwm,
                                   self.forest.context_len(r))
            if len(req.generated) >= req.max_new:
                req.state = DONE
                req.pending = None
                out[r] = req.generated[-1]
            else:
                req.pending = final_tok
                out[r] = final_tok
        return out

    # ------------------------------------------------------------------ #
    def run(self, max_steps: int = 64,
            on_step=None) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            if on_step is not None:
                on_step(self)
        self.flush_tokens()
        self._stream_ready()
        self._notify_done()
        return {r: req.generated for r, req in self.requests.items()}

    def release(self, rid: int) -> None:
        self.flush_tokens()
        req = self.requests.pop(rid)
        self.admission.remove(rid)      # queue entry + EDF bookkeeping
        if req.state == WAITING:
            for nid in req.pinned:
                node = self.forest.nodes.get(nid)
                if node is not None:
                    node.meta["pins"] = node.meta.get("pins", 0) - 1
                    self._maybe_free_node(node)
            req.pinned = []
            return
        if rid in self._prefilling:
            self._prefilling.remove(rid)
        if rid in self.forest.leaf_of:
            self._release_kv(rid)
        self._pending_ref.pop(rid, None)

    # ------------------------------------------------------------------ #
    # request lifecycle control + fault tolerance (DESIGN.md §12)
    # ------------------------------------------------------------------ #
    def cancel(self, rid: int) -> bool:
        """Cancel a request in any pre-terminal state.

        The KV it holds is released (waiting pins unwound, live drafts
        rolled back), its stream is closed via ``on_done(rid,
        "cancelled")``, and already-delivered tokens stand.  Returns
        ``False`` when the request is unknown or already terminal."""
        req = self.requests.get(rid)
        if req is None or req.state in TERMINAL:
            return False
        self.flush_tokens()    # deliverable tokens land before closing
        self._finish(req, CANCELLED, "cancelled")
        return True

    def _finish(self, req: Request, state: str, reason: str) -> None:
        """Centralised terminal transition for the failure states.

        Unwinds whatever stage the request is in — waiting (queue entry
        + pins), prefilling, or running (forest membership, drafts,
        deferred-token refs) — releases its KV, and closes the stream.
        ``FAILED`` force-frees the private leaf (possibly-poisoned KV
        must not become cache content)."""
        if req.state in TERMINAL and req.kv_freed:
            return
        rid = req.rid
        if req.state == WAITING:
            self.admission.remove(rid)
            for nid in req.pinned:
                node = self.forest.nodes.get(nid)
                if node is not None:
                    node.meta["pins"] = node.meta.get("pins", 0) - 1
                    self._maybe_free_node(node)
            req.pinned = []
        else:
            if rid in self._prefilling:
                self._prefilling.remove(rid)
            if rid in self.forest.leaf_of:
                self._release_kv(rid, force_leaf=state == FAILED)
            self._pending_ref.pop(rid, None)
            # the fused epoch's plan references the departed row (and
            # possibly its freed pages): force a rebuild
            self._plan_dirty = True
        req.pending = None
        req.kv_freed = True
        req.state = state
        req.finish_reason = reason
        self.stats[{CANCELLED: "cancelled", TIMED_OUT: "timed_out",
                    FAILED: "failed"}[state]] += 1
        self._fire_on_done(req)

    def _fail_request(self, rid: int, reason: str,
                      flush: bool = True) -> None:
        """Quarantine one request as FAILED without poisoning the batch."""
        req = self.requests.get(rid)
        if req is None or (req.state in TERMINAL and req.kv_freed):
            return
        if flush:
            self.flush_tokens()
        if reason == "nan_logits" and self._nan_dirty:
            # scrub injected NaN slots before the pages return to the
            # free list: a future tenant must never read them
            for page, off in self._nan_dirty:
                self.pool.k = self.pool.k.at[:, page, off].set(0.0)
            self._nan_dirty = []
        self._finish(req, FAILED, reason)

    def _fire_on_done(self, req: Request) -> None:
        """Close the stream exactly once; isolate a raising callback."""
        if req.notified:
            return
        req.notified = True
        self.admission.remove(req.rid)   # drop EDF deadline bookkeeping
        tm = self.telemetry
        if tm is not None:
            reason = req.finish_reason or "done"
            tm.end_all(req.rid)
            tm.instant(reason, track=req.rid,
                       args={"tokens": len(req.generated)})
            tm.metrics.counter("tokens_generated").inc(len(req.generated))
            if reason == "done":
                tm.metrics.counter("requests_done").inc()
            if req.first_tok_t is not None:
                tm.observe("ttft_s", req.first_tok_t - req.submit_t)
                tm.observe("e2e_s",
                           (req.last_tok_t or req.first_tok_t)
                           - req.submit_t)
                if req.last_tok_t is not None and len(req.generated) > 1:
                    tm.observe("tpot_s",
                               (req.last_tok_t - req.first_tok_t)
                               / (len(req.generated) - 1))
        try:
            if self.injector is not None and req.on_done is not None:
                spec = self.injector.take("callback", rid=req.rid)
                if spec is not None:
                    self.stats["faults_injected"] += 1
                    raise InjectedFault(
                        spec, f"injected on_done failure for request "
                              f"{req.rid}")
            if req.on_done is not None:
                req.on_done(req.rid, req.finish_reason or "done")
        except Exception:
            self.stats["callback_errors"] += 1
            if req.state == DONE:
                # the only visible casualty is this request's status
                if req.rid in self.forest.leaf_of:
                    self._release_kv(req.rid)
                    self._plan_dirty = True
                req.kv_freed = True
                req.state = FAILED
                req.finish_reason = "callback_error"
                self.stats["failed"] += 1

    def _notify_done(self) -> None:
        """Fire ``on_done`` for normally-completed requests whose stream
        has fully drained (failure states notify inside ``_finish``)."""
        for req in list(self.requests.values()):
            if req.state != DONE or req.notified:
                continue
            if req.on_token is not None and req.emitted < len(
                    req.generated):
                continue    # tokens still deferred: next boundary
            req.finish_reason = req.finish_reason or "done"
            self._fire_on_done(req)

    def _enforce_deadlines(self) -> None:
        """Step-boundary deadline sweep over every pre-terminal request
        (the waiting queue included): expired requests transition to
        TIMED_OUT with their KV released and their stream closed."""
        now = self.clock()
        for req in list(self.requests.values()):
            if req.state in TERMINAL:
                continue
            if req.deadline is not None and now >= req.deadline:
                self.flush_tokens()
                self._finish(req, TIMED_OUT, "deadline")
            elif (req.state == WAITING and req.queue_deadline is not None
                  and now >= req.queue_deadline):
                self._finish(req, TIMED_OUT, "queue_timeout")

    def check(self) -> None:
        """Serving-time invariant self-check (raises
        :class:`~repro.serving.faults.EngineInvariantError`).

        Consolidates the allocator's structural ``check()`` with the
        engine-level cross-structure invariants: every allocated page is
        owned by exactly one forest node (replicas and draft pages
        included), pin refcounts equal the waiting holders' pin lists,
        ``leaf_of`` is coherent with request states, deferred-token refs
        point at live deferred rows, and cache residency fits the pool.
        Run after every dispatch recovery and every ``check_every``
        steps; cheap enough for tests to call after each scenario."""
        self.stats["invariant_checks"] += 1
        failures: List[str] = []
        try:
            self.forest.validate()
        except AssertionError as e:
            failures.append(f"forest: {e}")
        try:
            self.pool.allocator.check()
        except AssertionError as e:
            failures.append(f"allocator: {e}")
        owned: Dict[int, int] = {}
        for node in self.forest.nodes.values():
            if node.id == tree_mod.ROOT_ID:
                continue
            reps = node.meta.get("replicas")
            pages = ([p for run in reps.values() for p in run]
                     if reps is not None else node.page_ids)
            for p in pages:
                owned[p] = owned.get(p, 0) + 1
        for p, n in owned.items():
            if n != 1:
                failures.append(f"page {p} owned by {n} nodes")
        used = self.pool.allocator.used_page_ids()
        leaked = sorted(set(used) - set(owned))
        dangling = sorted(set(owned) - set(used))
        if leaked:
            failures.append(
                f"{len(leaked)} leaked page(s) (allocated, owned by no "
                f"node): {leaked[:8]}")
        if dangling:
            failures.append(
                f"{len(dangling)} dangling page(s) (node-owned, not "
                f"allocated): {dangling[:8]}")
        pin_count: Dict[int, int] = {}
        for req in self.requests.values():
            for nid in req.pinned:
                pin_count[nid] = pin_count.get(nid, 0) + 1
        for node in self.forest.nodes.values():
            pins = node.meta.get("pins", 0)
            if pins != pin_count.get(node.id, 0):
                failures.append(
                    f"node {node.id} pins={pins} but "
                    f"{pin_count.get(node.id, 0)} holder(s) list it")
        for rid, req in self.requests.items():
            if (req.state in (PREFILL, RUNNING)
                    and rid not in self.forest.leaf_of):
                failures.append(f"live request {rid} has no forest leaf")
            if req.state == WAITING and rid in self.forest.leaf_of:
                failures.append(
                    f"waiting request {rid} still in the forest")
            if (req.state in TERMINAL and req.kv_freed
                    and rid in self.forest.leaf_of):
                failures.append(
                    f"finished request {rid} still holds forest KV")
        draft_virts = {v for st in self._drafts.values()
                       for v in st.virts}
        for rid in self.forest.leaf_of:
            if rid >= 0 and rid not in self.requests:
                failures.append(
                    f"forest request {rid} unknown to the engine")
            if rid < 0 and rid not in draft_virts:
                failures.append(
                    f"virtual query {rid} without a draft tree")
        for rid in self._pending_ref:
            req = self.requests.get(rid)
            if req is None or req.pending is not PENDING_DEVICE:
                failures.append(
                    f"dangling deferred-token ref for request {rid}")
        if self.cache is not None:
            resident = self.cache.resident_pages()
            total_used = sum(used.values()) if used else 0
            if resident > total_used:
                failures.append(
                    f"cache claims {resident} resident pages but only "
                    f"{total_used} are allocated")
        if failures:
            raise EngineInvariantError(failures)

    def shutdown(self) -> Dict[str, int]:
        """Graceful teardown: cancel all outstanding work, drop finished
        and cached KV, self-check, and return a leak summary
        (``used_pages`` must be 0 after a clean shutdown)."""
        for rid in sorted(self.requests):
            if self.requests[rid].state not in TERMINAL:
                self.cancel(rid)
        self.flush_tokens()
        self._stream_ready()
        self._notify_done()
        for rid, req in sorted(self.requests.items()):
            if rid in self.forest.leaf_of:    # DONE, KV still resident
                self._release_kv(rid)
                req.kv_freed = True
        if self.cache is not None:
            self._evict_cached(self.pool.num_pages)
        self.check()
        return {"used_pages": self.pool.allocator.num_used,
                "requests": len(self.requests)}
