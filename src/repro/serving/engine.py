"""Prefix-shared decode engine (the paper's vLLM-integration analogue).

Continuous-batching decode loop with CoDec as the attention backend:

* prompts are radix-inserted into a ``PrefixForest``; already-cached
  nodes are *not* recomputed (prefill prefix reuse) — only the new leaf's
  KV is computed, attending to the gathered cached prefix;
* decode attention = **frozen CoDec plan** over all full pages (rebuilt
  only when a leaf crosses a page boundary or batch membership changes —
  the paper's "reuse a division plan for multiple decoding steps") POR-
  merged with a **tail attention** over each request's growing last page;
* KV pages live in a ``PagedKVPool``; pages of shared prefixes are
  reference-counted and freed when the last request leaves;
* Mamba layers (hybrid archs) keep per-request recurrent state, with
  end-of-node state caching so shared prefixes are also not recomputed
  for SSM mixers (the SSM analogue of prefix caching — see DESIGN.md §5);
* decode attention backends are resolved by NAME through
  ``kernels.registry`` (``codec-pallas`` / ``codec-xla`` / ``hydragen``
  prefix-shared, ``flash`` per-request baseline, ``ref`` oracle); the
  backend's ``prepare(plan)`` output is cached across steps and its
  ``partials`` are POR-merged with the tail-page attention — see
  DESIGN.md §2–§3 for the contract.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LayerKind, ModelConfig
from ..core import plan as plan_mod
from ..core import tree as tree_mod
from ..core.cost_model import CostModel
from ..kernels import ops, ref as ref_mod, registry as registry_mod
from ..models import layers as L
from ..models import mamba as M
from ..models import transformer as T
from . import sampler
from .kv_cache import PagedKVPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    generated: List[int] = dataclasses.field(default_factory=list)
    pending: Optional[int] = None      # sampled, not yet appended
    max_new: int = 16
    done: bool = False


def flat_layers(cfg: ModelConfig, params) -> List[Tuple[LayerKind, Dict]]:
    out = []
    if cfg.num_periods > 0:
        for pi in range(cfg.num_periods):
            period = jax.tree.map(lambda x: x[pi], params["blocks"])
            for i in range(cfg.period):
                out.append((cfg.layer_pattern[i], period[f"sub{i}"]))
    for i in range(cfg.remainder_layers):
        out.append((cfg.layer_pattern[i], params["rem"][i]))
    return out


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 page_size: int = 16, num_pages: int = 4096,
                 backend: str = "codec-pallas",
                 num_lanes: int = 2, max_q: int = 32,
                 max_kv_per_task: int = 2048,
                 replan_interval: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0):
        assert cfg.encoder_layers == 0, "engine serves decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self._backend = registry_mod.get(backend)
        if (cfg.sliding_window and not self._backend.supports_window
                and any(k.mixer == "attn_local"
                        for k in cfg.layer_pattern)):
            raise ValueError(f"backend {backend!r} cannot serve "
                             f"sliding-window layers")
        self.page_size = page_size
        self.num_lanes = num_lanes
        self.max_q = max_q
        self.max_kv_per_task = max_kv_per_task
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.layers = flat_layers(cfg, params)
        self.attn_layer_idx = {j: a for a, j in enumerate(
            j for j, (k, _) in enumerate(self.layers)
            if k.mixer in ("attn", "attn_local"))}
        n_attn = len(self.attn_layer_idx)
        self.pool = PagedKVPool(max(n_attn, 1), num_pages, page_size,
                                max(cfg.num_kv_heads, 1),
                                max(cfg.head_dim, 1))
        self.forest = tree_mod.PrefixForest(page_size)
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.cost_model = CostModel(max(cfg.num_heads, 1),
                                    max(cfg.num_kv_heads, 1),
                                    max(cfg.head_dim, 1),
                                    page_size=page_size)
        # mamba per-request state, keyed by layer index
        self.mamba_state: Dict[int, Any] = {}
        # plans keyed by window size (0 = full attention)
        self._plans: Dict[int, Any] = {}
        self._plan_dirty = True
        self.replan_interval = replan_interval
        self._steps_since_plan = 0
        self.stats = {"steps": 0, "replans": 0, "plan_time": 0.0,
                      "decode_time": 0.0, "prefill_tokens": 0}

    # ------------------------------------------------------------------ #
    # request admission / prefill with prefix reuse
    # ------------------------------------------------------------------ #
    def add_request(self, prompt: List[int], max_new: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.forest.insert_tokens(rid, np.asarray(prompt, np.int32))
        req = Request(rid, list(prompt), max_new=max_new)
        self.requests[rid] = req
        self._ensure_pages(rid)
        self._prefill(req)
        self._plan_dirty = True
        return rid

    def _ensure_pages(self, rid: int) -> None:
        """Allocate pages for any node on the path lacking them."""
        for node in self.forest.path(rid):
            need = -(-max(node.length, 1) // self.page_size)
            if len(node.page_ids) < need:
                node.page_ids += self.pool.allocator.alloc(
                    need - len(node.page_ids))

    def _gather_prefix(self, layer_attn: int, nodes) -> Tuple:
        """Dense (ctx, n_kv, hd) for a list of filled nodes."""
        ks, vs = [], []
        for node in nodes:
            k, v = self.pool.gather_context(layer_attn, node.page_ids,
                                            node.length)
            ks.append(k)
            vs.append(v)
        if not ks:
            hkv, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            z = jnp.zeros((0, hkv, hd), self.pool.k.dtype)
            return z, z
        return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)

    def _prefill(self, req: Request) -> None:
        """Compute KV (and SSM states) for the request's unfilled suffix.

        Attention KV of filled prefix nodes is reused (gathered from the
        paged pool); SSM layers resume from the deepest node boundary with
        a cached state and states are (re-)cached at every node boundary
        inside the recomputed span so later siblings resume exactly.
        """
        cfg = self.cfg
        path = self.forest.path(req.rid)
        filled_nodes, todo = [], []
        for node in path:
            if node.meta.get("filled", 0) >= node.length and node.length > 0:
                filled_nodes.append(node)
            elif node.length > 0:
                todo.append(node)
        if not todo:
            # fully cached prompt: recompute the last node to get logits
            todo = [filled_nodes.pop()] if filled_nodes else []
        ctx_start = sum(n.length for n in filled_nodes)

        has_mamba = any(k.mixer == "mamba" for k, _ in self.layers)
        mamba_start = 0
        mamba_init: Dict[int, Any] = {}
        if has_mamba:
            pos = 0
            for node in filled_nodes:
                pos += node.length
                if "ssm" in node.meta:
                    mamba_start, mamba_init = pos, node.meta["ssm"]
        span_start = min(ctx_start, mamba_start) if has_mamba else ctx_start
        tokens = np.asarray(req.prompt[span_start:], np.int32)
        Tn = len(tokens)
        self.stats["prefill_tokens"] += Tn
        positions = (span_start + np.arange(Tn))[None]           # (1, Tn)

        # node segments covering the span (for KV writes + state caching)
        segments = []        # (node, lo, hi) in span-local coordinates
        off = 0
        for node in path:
            lo = max(0, off - span_start)
            hi = max(0, off + node.length - span_start)
            if hi > lo:
                segments.append((node, lo, hi))
            off += node.length

        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None],
                     jnp.asarray(positions))
        prefix_nodes = [n for n in filled_nodes
                        if n.end_pos <= span_start]   # attention KV to reuse

        new_kv_writes = []  # (layer_attn, k (Tn,kv,hd), v)
        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 jnp.asarray(positions))
                pk, pv = self._gather_prefix(la, prefix_nodes)
                k_all = jnp.concatenate([pk.astype(k_new.dtype)[None],
                                         k_new], 1)
                v_all = jnp.concatenate([pv.astype(v_new.dtype)[None],
                                         v_new], 1)
                o = L.mha(q, k_all, v_all, causal=True, window=window,
                          softcap=cfg.attn_logit_softcap,
                          q_positions=jnp.asarray(positions),
                          kv_positions=jnp.arange(span_start + Tn)[None])
                y = L.dense(p["attn"]["wo"],
                            o.reshape(1, Tn, cfg.num_heads * cfg.head_dim))
                new_kv_writes.append((la, k_new[0], v_new[0]))
                x = x + y
            elif kind.mixer == "mamba":
                state = mamba_init.get(j)
                ys = []
                for node, lo, hi in segments:
                    y_seg, state = self._mamba_prefill(p["mamba"],
                                                       h[:, lo:hi], state)
                    ys.append(y_seg)
                    # cache the end-of-node state (shared nodes only; a
                    # leaf's state keeps moving, cached per request below)
                    if node.id != self.forest.leaf_of[req.rid]:
                        node.meta.setdefault("ssm", {})[j] = state
                y = jnp.concatenate(ys, 1)
                self.mamba_state.setdefault(j, {})[req.rid] = state
                x = x + y
            if kind.ffn != "none":
                h2 = L.apply_norm(p["ln2"], x, cfg)
                if kind.ffn == "moe":
                    y2, _ = L.apply_moe(p["ffn"], cfg, h2)
                else:
                    y2 = L.apply_mlp(p["ffn"], cfg, h2)
                x = x + y2

        # write new KV into unfilled pages only
        offs, pages, kv_rows = [], [], []
        for node, lo, hi in segments:
            start = max(node.meta.get("filled", 0), 0)
            node_lo_global = span_start + lo  # == node.start_pos
            for t in range(node.length):
                if t < start:
                    continue
                if lo + t >= hi:
                    break
                pages.append(node.page_ids[t // self.page_size])
                offs.append(t % self.page_size)
                kv_rows.append(lo + t)
            node.meta["filled"] = node.length
        if kv_rows:
            rows = jnp.asarray(np.asarray(kv_rows))
            for la, k_new, v_new in new_kv_writes:
                self.pool.write_tokens(la, np.asarray(pages),
                                       np.asarray(offs),
                                       k_new[rows], v_new[rows])
        logits = T._unembed(self.params, cfg, x)[0, -1]
        self.key, sk = jax.random.split(self.key)
        req.pending = int(sampler.sample(logits[None], sk,
                                         self.temperature)[0])

    def _mamba_prefill(self, p, h, init):
        cfg = self.cfg
        if init is None:
            return M.mamba_forward(p, cfg, h)
        conv0, ssm0 = init
        # run chunked SSD from a carried state
        zxbcdt = h @ p["in_proj"]["w"]
        z, xBC_raw, dt = M._split_proj(cfg, zxbcdt)
        xBC = M._causal_conv(xBC_raw, p["conv_w"], p["conv_b"],
                             init_state=conv0)
        d_in, S = cfg.d_inner, cfg.ssm_state
        B, Tn = h.shape[0], h.shape[1]
        x_ssm = xBC[..., :d_in].reshape(B, Tn, cfg.ssm_heads,
                                        cfg.ssm_head_dim)
        Bm = xBC[..., d_in:d_in + S]
        Cm = xBC[..., d_in + S:]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, final = M.ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 init_state=ssm0)
        y = y + x_ssm.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
        y = y.reshape(B, Tn, d_in)
        y = M._gated_norm(y, z, p["norm"], cfg.norm_eps)
        out = y @ p["out_proj"]["w"]
        K = cfg.ssm_conv
        conv_tail = jnp.concatenate([conv0, xBC_raw.astype(jnp.float32)],
                                    1)[:, -(K - 1):]
        return out, (conv_tail, final)

    # ------------------------------------------------------------------ #
    # plan management
    # ------------------------------------------------------------------ #
    def _windows(self) -> List[int]:
        ws = set()
        for kind, _ in self.layers:
            if kind.mixer == "attn":
                ws.add(0)
            elif kind.mixer == "attn_local":
                ws.add(self.cfg.sliding_window)
        return sorted(ws)

    def _active_rows(self) -> List[int]:
        return [r for r in sorted(self.requests)
                if not self.requests[r].done]

    def _rebuild_plans(self) -> None:
        t0 = time.perf_counter()
        rows = self._active_rows()
        req_rows = {r: i for i, r in enumerate(rows)}
        ps = self.page_size
        truncate = {}
        for r in rows:
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tail_start = max(0, ((leaf.length - 1) // ps) * ps)
            truncate[leaf.id] = tail_start
        build = (plan_mod.flash_plan if self._backend.plan_kind == "flash"
                 else plan_mod.build_plan)
        self._plans = {}
        for w in self._windows():
            p = build(
                self.forest, self.cost_model, self.num_lanes, self.max_q,
                self.max_kv_per_task, req_rows=req_rows, window=w,
                truncate=truncate)
            p = plan_mod.pad_plan(p)
            self._plans[w] = (p, self._backend.prepare(p))
        self._rows = rows
        self._plan_dirty = False
        self._steps_since_plan = 0
        self.stats["replans"] += 1
        self.stats["plan_time"] += time.perf_counter() - t0

    def _advance_qpos(self) -> None:
        """Cheap per-step plan refresh: live queries moved one position."""
        for w, (p, _) in list(self._plans.items()):
            slot = np.arange(p.max_q)[None, :]
            live = slot < p.task_qnum[:, None]
            p.q_pos = p.q_pos + live.astype(np.int32)
            self._plans[w] = (p, self._backend.prepare(p))

    # ------------------------------------------------------------------ #
    # decode step
    # ------------------------------------------------------------------ #
    def step(self) -> Dict[int, int]:
        """Append pending tokens, decode one new token per active request."""
        cfg = self.cfg
        rows = self._active_rows()
        if not rows:
            return {}
        t0 = time.perf_counter()
        # 1. append pending tokens to leaves (grow pages as needed)
        tokens = []
        for r in rows:
            req = self.requests[r]
            tok = req.pending
            self.forest.append_token(r, tok)
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            if -(-leaf.length // self.page_size) > len(leaf.page_ids):
                leaf.page_ids += self.pool.allocator.alloc(1)
                self._plan_dirty = True
            tokens.append(tok)
        if (self.replan_interval is not None
                and self._steps_since_plan >= self.replan_interval):
            self._plan_dirty = True
        if self._plan_dirty or rows != getattr(self, "_rows", None):
            self._rebuild_plans()
        else:
            self._advance_qpos()
        self._steps_since_plan += 1

        B = len(rows)
        ctx = np.array([self.forest.context_len(r) for r in rows], np.int32)
        q_pos = jnp.asarray(ctx - 1)
        x = T._embed(self.params, cfg, jnp.asarray(tokens)[None].T,
                     q_pos[:, None])                       # (B,1,d)

        # tail page info
        tail_pages, tail_base, tail_off = [], [], []
        for i, r in enumerate(rows):
            leaf = self.forest.nodes[self.forest.leaf_of[r]]
            tp = (leaf.length - 1) // self.page_size
            tail_pages.append(leaf.page_ids[tp])
            tail_base.append(leaf.start_pos + tp * self.page_size)
            tail_off.append((leaf.length - 1) % self.page_size)
        tail_pages = np.asarray(tail_pages)
        tail_base = jnp.asarray(np.asarray(tail_base))
        tail_off = np.asarray(tail_off)

        for j, (kind, p) in enumerate(self.layers):
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                la = self.attn_layer_idx[j]
                window = (cfg.sliding_window if kind.mixer == "attn_local"
                          else 0)
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                self.pool.write_tokens(la, tail_pages, tail_off,
                                       k_new[:, 0], v_new[:, 0])
                k_pool, v_pool = self.pool.layer_pools(la)
                qb = q[:, 0]                                # (B, h, hd)
                o = self._attend(qb, k_pool, v_pool, window, B,
                                 tail_pages, tail_base, q_pos)
                y = L.dense(p["attn"]["wo"],
                            o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            elif kind.mixer == "mamba":
                states = self.mamba_state[j]
                conv = jnp.concatenate([states[r][0] for r in rows], 0)
                ssm = jnp.concatenate([states[r][1] for r in rows], 0)
                y, (conv_n, ssm_n) = M.mamba_decode(p["mamba"], cfg, h,
                                                    conv, ssm)
                for i, r in enumerate(rows):
                    states[r] = (conv_n[i:i + 1], ssm_n[i:i + 1])
                x = x + y
            if kind.ffn != "none":
                h2 = L.apply_norm(p["ln2"], x, cfg)
                if kind.ffn == "moe":
                    y2, _ = L.apply_moe(p["ffn"], cfg, h2)
                else:
                    y2 = L.apply_mlp(p["ffn"], cfg, h2)
                x = x + y2

        logits = T._unembed(self.params, cfg, x)[:, 0]      # (B, V)
        self.key, sk = jax.random.split(self.key)
        toks = np.asarray(sampler.sample(logits, sk, self.temperature))
        out = {}
        for i, r in enumerate(rows):
            req = self.requests[r]
            req.generated.append(int(tokens[i]))
            req.pending = int(toks[i])
            out[r] = int(toks[i])
            if len(req.generated) >= req.max_new:
                req.done = True
                self._plan_dirty = True
        self.stats["steps"] += 1
        self.stats["decode_time"] += time.perf_counter() - t0
        return out

    def _attend(self, qb, k_pool, v_pool, window, B,
                tail_pages, tail_base, q_pos):
        plan, prepared = self._plans[window]
        # frozen part: backend partials over all full pages
        o_f, m_f, l_f = self._backend.partials(
            qb, k_pool, v_pool, plan, prepared, window=window)
        # tail part: each request's growing last page
        kt = k_pool[jnp.asarray(tail_pages)]
        vt = v_pool[jnp.asarray(tail_pages)]
        o_t, m_t, l_t = ops.single_page_attention(
            qb, kt, vt, tail_base, q_pos, window=window)
        o, _, _ = ref_mod.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
        return o.astype(qb.dtype)

    # ------------------------------------------------------------------ #
    def run(self, max_steps: int = 64) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.step():
                break
        return {r: req.generated for r, req in self.requests.items()}

    def release(self, rid: int) -> None:
        req = self.requests.pop(rid)
        leaf = self.forest.leaf_of[rid]
        # pages of nodes used only by this request are freed
        for node in reversed(self.forest.path(rid)):
            node.requests.remove(rid)
            if not node.requests and not node.children:
                self.pool.allocator.release(node.page_ids)
                parent = self.forest.nodes[node.parent]
                parent.children.remove(node.id)
                del self.forest.nodes[node.id]
        del self.forest.leaf_of[rid]
        for st in self.mamba_state.values():
            st.pop(rid, None)
        self._plan_dirty = True
