"""Fused device-side decode step: one jitted dispatch per token.

The eager engine loop (``DecodeEngine._decode_phase_eager``) drives each
decode token through dozens of small jitted ops — per-layer host→device
conversions, per-layer KV-pool scatters, a python loop over layers —
so wall-clock TPOT is dominated by dispatch overhead and the PAC
kernel's memory-access savings never reach end-to-end numbers.  This
module collapses the whole step into **one** jitted, donated,
shape-bucketed device function:

* the layer stack is applied through ``transformer.scan_layer_stack``
  (``lax.scan`` over the period-stacked parameter pytree, remainder
  unrolled) so the lowered HLO stays O(period);
* tail-page metadata is pre-batched into :class:`StepBase` device
  arrays once per **plan epoch** (the interval between plan rebuilds);
  within an epoch the only per-step inputs are the previous step's
  token array, the PRNG key, and the epoch-relative step counter
  ``delta`` (query positions and tail slots advance as
  ``base + delta`` on device);
* KV tail writes, the backend's frozen-plan ``partials``
  (``AttentionBackend.partials_arrays_fn`` — the jit-safe contract),
  the tail-page attention, the POR merge, FFN/MoE/Mamba mixing,
  unembedding, and sampling all trace into the same program;
* the KV pool and batched Mamba state are **donated**
  (:class:`StepState`), so XLA updates them in place;
* every shape is bucketed (batch rows and plan arrays to powers of two
  — ``core.plan.bucket_plan``) so arrivals/completions/evictions reuse
  the compiled program; padded rows carry ``q_pos = -1`` and write
  their tail KV to the pool's trash page.

The engine dispatches step *t+1* while the host still holds step *t*'s
token array as an opaque future — host⇄device syncs happen only at
plan-rebuild and admission boundaries (see ``DecodeEngine.flush_tokens``).

``distributed/step_fn.py`` is the SPMD sibling: the same program
traced under ``shard_map`` over a ``(data, model)`` mesh, with
per-shard plans and a cross-device POR merge (DESIGN.md §9); it reuses
:class:`StepState` and the donation-warning shim from here.
"""

from __future__ import annotations

import warnings
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops, ref as ref_mod
from ..models import layers as L
from ..models import mamba as M
from ..models import transformer as T
from . import sampler

_DONATION_WARNING_SILENCED = False


def _silence_donation_warning() -> None:
    """CPU XLA often cannot honour buffer donation; the fallback copy is
    correct, just slower — don't warn about it on every fused dispatch.
    Installed once, and only when a fused step is actually built, so
    processes that never use the fused path keep the warning."""
    global _DONATION_WARNING_SILENCED
    if not _DONATION_WARNING_SILENCED:
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        _DONATION_WARNING_SILENCED = True


class StepBase(NamedTuple):
    """Per-epoch device inputs: constant between plan rebuilds."""

    row_valid: jnp.ndarray   # (B,) bool — padded bucket rows are False
    q_pos0: jnp.ndarray      # (B,) int32 query position at delta=0 (-1 pads)
    tail_page: jnp.ndarray   # (B,) int32 tail KV page (pads → pool trash)
    tail_base: jnp.ndarray   # (B,) int32 abs position of the page's slot 0
    tail_off0: jnp.ndarray   # (B,) int32 in-page slot written at delta=0


class StepState(NamedTuple):
    """Donated device state threaded through consecutive fused steps."""

    pool_k: jnp.ndarray      # (n_attn, P+1, page, n_kv, hd) paged KV pool
    pool_v: jnp.ndarray
    conv: jnp.ndarray        # (n_mamba, B, K-1, conv_dim) f32 SSM conv state
    ssm: jnp.ndarray         # (n_mamba, B, H, P_h, S) f32 SSM recurrent state


class SpecState(NamedTuple):
    """Donated pool state threaded through fused verification dispatches
    (speculative mode is attention-only, so no SSM state rides along)."""

    pool_k: jnp.ndarray      # (n_attn, P+1, page, n_kv, hd)
    pool_v: jnp.ndarray


def make_spec_step_fn(cfg: ModelConfig, backend, windows: Tuple[int, ...]):
    """Build the fused speculative *verification* dispatch (DESIGN §10).

    Returns a jitted callable

        ``fn(params, state, tokens, q_pos, write_page, write_off,
        prepared) -> (greedy_tokens, row_ok, state')``

    scoring a whole batch of verification queries — every request's
    committed-tail base query plus one query per draft-tree node — in
    ONE device dispatch.  Per attention layer it projects q/k/v for all
    rows, scatters the new K/V into each row's ``(write_page,
    write_off)`` slot (draft nodes own their page's slot 0; padded
    bucket rows hit the pool's trash page), then runs the backend's
    ``partials_arrays_fn`` over the *verify plan* — which covers the
    entire forest including partial tail pages and draft nodes, so no
    tail/POR split is needed and the partials' ``o`` is already the
    full softmax output.  Greedy argmax replaces sampling (speculative
    mode is greedy-only; acceptance happens on the host).

    Shapes bucket exactly like the regular fused step: the row axis to
    ``bucket_pow2`` and the plan through ``core.plan.bucket_plan``, so
    draft trees of varying shape reuse the compiled program.
    """
    _silence_donation_warning()
    win_slot = {w: i for i, w in enumerate(windows)}

    def step(params, state: SpecState, tokens: jnp.ndarray,
             q_pos: jnp.ndarray, write_page: jnp.ndarray,
             write_off: jnp.ndarray, prepared: Tuple[Any, ...]):
        B = tokens.shape[0]
        x = T._embed(params, cfg, tokens[:, None], q_pos[:, None])

        def body(c, kind, p, la, lm):
            x, pool_k, pool_v = c
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                w = cfg.sliding_window if kind.mixer == "attn_local" else 0
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                pool_k = pool_k.at[la, write_page, write_off].set(
                    k_new[:, 0].astype(pool_k.dtype))
                pool_v = pool_v.at[la, write_page, write_off].set(
                    v_new[:, 0].astype(pool_v.dtype))
                o, _, _ = backend.partials_arrays_fn(
                    q[:, 0], pool_k[la], pool_v[la],
                    prepared[win_slot[w]], num_queries=B, window=w)
                y = L.dense(p["attn"]["wo"],
                            o.astype(q.dtype).reshape(
                                B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)
            return (x, pool_k, pool_v)

        x, pool_k, pool_v = T.scan_layer_stack(
            cfg, params, body, (x, state.pool_k, state.pool_v))
        with jax.named_scope("codec.spec_verify"):
            logits = T._unembed(params, cfg, x)[:, 0]       # (B, V)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            ok = jnp.isfinite(logits).all(-1)               # (B,) NaN guard
        return toks, ok, SpecState(pool_k, pool_v)

    return jax.jit(step, donate_argnums=(1,))


def make_step_fn(cfg: ModelConfig, backend, windows: Tuple[int, ...],
                 temperature: float):
    """Build the fused decode step for one engine configuration.

    Returns a jitted callable

        ``fn(params, state, tokens, key, base, delta, prepared)
        -> (tokens', row_ok, key', state')``

    ``row_ok`` is a per-row finite-logits flag — essentially free to
    compute (one reduction over an array already resident for sampling)
    and carried with the deferred token array so the engine's optional
    NaN guard can quarantine a poisoned row at the next flush without
    adding a sync point.

    where ``state`` (:class:`StepState`) is donated, ``tokens`` is the
    (bucketed) batch of tokens appended this step, ``delta`` the
    epoch-relative step counter (traced — no recompile per step), and
    ``prepared`` a tuple of the backend's prepared plan arrays, one per
    attention window in ``windows``.  ``backend`` must satisfy the
    registry's jit-safe contract (``partials_arrays_fn``/``advance_fn``).
    """
    _silence_donation_warning()
    win_slot = {w: i for i, w in enumerate(windows)}

    def step(params, state: StepState, tokens: jnp.ndarray, key,
             base: StepBase, delta, prepared: Tuple[Any, ...]):
        B = tokens.shape[0]
        dlt = jnp.asarray(delta, jnp.int32) * base.row_valid.astype(jnp.int32)
        q_pos = base.q_pos0 + dlt
        tail_off = base.tail_off0 + dlt
        with jax.named_scope("codec.plan_advance"):
            advanced = tuple(backend.advance_fn(p, delta) for p in prepared)
        with jax.named_scope("codec.embed"):
            x = T._embed(params, cfg, tokens[:, None],
                         q_pos[:, None])                    # (B,1,d)

        def body(c, kind, p, la, lm):
            x, pool_k, pool_v, conv_all, ssm_all = c
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                w = cfg.sliding_window if kind.mixer == "attn_local" else 0
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                pool_k = pool_k.at[la, base.tail_page, tail_off].set(
                    k_new[:, 0].astype(pool_k.dtype))
                pool_v = pool_v.at[la, base.tail_page, tail_off].set(
                    v_new[:, 0].astype(pool_v.dtype))
                k_pool, v_pool = pool_k[la], pool_v[la]
                qb = q[:, 0]                                # (B, h, hd)
                o_f, m_f, l_f = backend.partials_arrays_fn(
                    qb, k_pool, v_pool, advanced[win_slot[w]],
                    num_queries=B, window=w)
                kt = k_pool[base.tail_page]
                vt = v_pool[base.tail_page]
                o_t, m_t, l_t = ops.single_page_attention(
                    qb, kt, vt, base.tail_base, q_pos, window=w)
                o, _, _ = ref_mod.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
                y = L.dense(p["attn"]["wo"],
                            o.astype(qb.dtype).reshape(
                                B, 1, cfg.num_heads * cfg.head_dim))
                x = x + y
            elif kind.mixer == "mamba":
                y, (conv_n, ssm_n) = M.mamba_decode(
                    p["mamba"], cfg, h, conv_all[lm], ssm_all[lm])
                conv_all = conv_all.at[lm].set(conv_n)
                ssm_all = ssm_all.at[lm].set(ssm_n)
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)
            return (x, pool_k, pool_v, conv_all, ssm_all)

        x, pool_k, pool_v, conv_all, ssm_all = T.scan_layer_stack(
            cfg, params, body,
            (x, state.pool_k, state.pool_v, state.conv, state.ssm))
        with jax.named_scope("codec.sample"):
            logits = T._unembed(params, cfg, x)[:, 0]       # (B, V)
            key, sk = jax.random.split(key)
            toks = sampler.sample(logits, sk, temperature)
            ok = jnp.isfinite(logits).all(-1)               # (B,) NaN guard
        return toks, ok, key, StepState(pool_k, pool_v, conv_all, ssm_all)

    return jax.jit(step, donate_argnums=(1,))
