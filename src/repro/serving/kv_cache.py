"""Paged KV-cache pool + page allocator (PagedAttention layout, paper §4.1/§6).

The pool holds all attention layers' KV pages:
``k/v: (n_attn_layers, num_pages, page_size, n_kv, head_dim)``.
Pages are allocated from a free list with reference counts so prefix nodes
shared by multiple requests are freed only when the last request releases
them.  The forest nodes record their ``page_ids``; the plan compiler reads
them directly — the CoDec kernel follows this exact layout.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PageAllocator:
    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        # accounting (watermarks / eviction diagnostics)
        self.peak_used = 0
        self.total_allocs = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._refs)

    def occupancy(self) -> float:
        return self.num_used / max(self.num_pages, 1)

    def alloc(self, n: int, hint: Optional[int] = None) -> List[int]:
        """``hint`` (a forest node id) is a placement affinity key; the
        single-shard allocator ignores it (the sharded pool's allocator
        uses it to keep a node's pages together / sequence-split them)."""
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.total_allocs += n
        self.peak_used = max(self.peak_used, self.num_used)
        return pages

    def retain(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"retain of unallocated page id {p}")
            self._refs[p] += 1

    def release(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"release of unallocated page id {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)

    def used_page_ids(self) -> Dict[int, int]:
        """Snapshot of allocated pages -> refcount (``DecodeEngine.check``
        cross-references this against forest node ownership)."""
        return dict(self._refs)

    def check(self) -> None:
        """Structural invariants (tests call this after workloads)."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate page ids on the free list")
        if free & set(self._refs):
            raise AssertionError("page both free and referenced")
        if len(free) + len(self._refs) != self.num_pages:
            raise AssertionError(
                f"page partition broken: {len(free)} free + "
                f"{len(self._refs)} used != {self.num_pages}")
        if any(r <= 0 for r in self._refs.values()):
            raise AssertionError("non-positive refcount")


class PagedKVPool:
    """Device-resident paged pool for all attention layers."""

    def __init__(self, n_layers: int, num_pages: int, page_size: int,
                 n_kv: int, head_dim: int, dtype=jnp.float32):
        self.n_layers = n_layers
        self.num_pages = num_pages
        self.page_size = page_size
        # one extra physical page past the allocator's range: a write
        # sink for padded batch rows of the fused decode step (their
        # scattered tail KV must land somewhere that no plan ever reads)
        self.k = jnp.zeros((n_layers, num_pages + 1, page_size, n_kv,
                            head_dim), dtype)
        self.v = jnp.zeros_like(self.k)
        self.allocator = PageAllocator(num_pages)

    @property
    def trash_page(self) -> int:
        """Physical page id of the write sink (never allocated)."""
        return self.num_pages

    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    @property
    def page_bytes(self) -> int:
        """K+V bytes of one page across all attention layers."""
        per = self.k.shape[0] * self.k.shape[2] * self.k.shape[3] \
            * self.k.shape[4]
        return 2 * per * self.k.dtype.itemsize

    def occupancy(self) -> float:
        return self.allocator.occupancy()

    def layer_pools(self, layer: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.k[layer], self.v[layer]

    def write_tokens(self, layer: int, pages: np.ndarray, offsets: np.ndarray,
                     k_new: jnp.ndarray, v_new: jnp.ndarray) -> None:
        """Scatter n tokens into (page, offset) slots of one layer.

        pages/offsets: (n,); k_new/v_new: (n, n_kv, head_dim).
        """
        li = jnp.full(pages.shape, layer, jnp.int32)
        pg = jnp.asarray(pages, jnp.int32)
        of = jnp.asarray(offsets, jnp.int32)
        self.k = self.k.at[li, pg, of].set(k_new.astype(self.k.dtype))
        self.v = self.v.at[li, pg, of].set(v_new.astype(self.v.dtype))

    def copy_slots(self, src_pages: np.ndarray, src_offs: np.ndarray,
                   dst_pages: np.ndarray, dst_offs: np.ndarray) -> None:
        """Copy token slots across pages, all layers at once.

        The speculative engine's commit path: an accepted draft token's
        KV was computed into its draft node's page during verification;
        committing moves it to the request's leaf tail slot so the draft
        page can be released and the committed layout stays identical to
        what non-speculative decode would have produced.
        """
        sp = jnp.asarray(src_pages, jnp.int32)
        so = jnp.asarray(src_offs, jnp.int32)
        dp = jnp.asarray(dst_pages, jnp.int32)
        do = jnp.asarray(dst_offs, jnp.int32)
        self.k = self.k.at[:, dp, do].set(self.k[:, sp, so])
        self.v = self.v.at[:, dp, do].set(self.v[:, sp, so])

    def gather_context(self, layer: int, pages: List[int], length: int,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Dense (length, n_kv, hd) view of a page run (prefill reuse)."""
        idx = jnp.asarray(pages, jnp.int32)
        ps = self.page_size
        k = self.k[layer, idx].reshape(len(pages) * ps, *self.k.shape[3:])
        v = self.v[layer, idx].reshape(len(pages) * ps, *self.v.shape[3:])
        return k[:length], v[:length]

    def bytes_used(self) -> int:
        return int(self.k.size + self.v.size) * self.k.dtype.itemsize
