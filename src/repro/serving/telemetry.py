"""Engine-wide telemetry: metrics registry + structured tracing (DESIGN §13).

One :class:`Telemetry` object per engine owns

* a :class:`~repro.core.metrics.MetricsRegistry` pre-registered with the
  serving instrument catalog (:data:`METRIC_CATALOG`), kept in sync with
  the engine's cumulative ``stats``/cache/pool state once per step;
* per-request and engine-track *spans* emitted through an injectable
  :class:`TraceSink` and exportable as Chrome trace-event JSON
  (``export_trace`` — load the file in Perfetto / ``chrome://tracing``);
* the sampled-profiling policy (``profile_every=N``): ``should_profile``
  tells the fused decode path which steps to block on the device and
  split into dispatch/device/flush phases, leaving every other step on
  the async fast path.

Span timestamps come from the engine's injectable clock (DESIGN §12), so
a fake stepped clock yields byte-identical traces across runs.  All of
this layer only *reads* engine state — token streams are byte-identical
with telemetry on or off (asserted by tests/test_telemetry.py).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from ..core import metrics as metrics_mod

# track key for engine-scoped (non-request) spans
ENGINE = "engine"

# pid values group Perfetto tracks: one process for the engine phases,
# one whose threads are the individual requests
_PID_ENGINE = 1
_PID_REQUESTS = 2

METRICS_SCHEMA = "codec-metrics/1"

# name -> (kind, help).  Histograms observe seconds unless named _bytes.
METRIC_CATALOG: Dict[str, Tuple[str, str]] = {
    # request lifecycle
    "requests_submitted": ("counter", "add_request calls accepted"),
    "requests_admitted": ("counter", "admissions (re-admissions incl.)"),
    "requests_done": ("counter", "requests finished with reason=done"),
    "requests_failed": ("counter", "requests quarantined FAILED"),
    "requests_cancelled": ("counter", "requests cancelled"),
    "requests_timed_out": ("counter", "deadline/queue-timeout expiries"),
    "preemptions": ("counter", "evict-and-requeue preemptions"),
    "reclaims": ("counter", "finished-KV reclaims under pressure"),
    "tokens_generated": ("counter", "tokens entered committed streams"),
    # prefill
    "prefill_tokens": ("counter", "prompt tokens prefilled"),
    "recompute_tokens": ("counter", "tokens recomputed after eviction"),
    "prefill_chunks": ("counter", "chunked prefill continuations"),
    "prefill_stalls": ("counter", "prefill chunks stalled on pages"),
    # cascade prefill (DESIGN §14)
    "cascade_groups": ("counter", "cascade group advances (>=2 members)"),
    "cascade_shared_tokens": ("counter",
                              "shared-span tokens siblings reused "
                              "(computed once, saved N-1 times)"),
    "cascade_suffix_tokens": ("counter",
                              "suffix tokens in batched dispatches"),
    "cascade_batches": ("counter", "batched suffix prefill dispatches"),
    # decode machinery
    "engine_steps": ("counter", "engine step() calls"),
    "decode_steps": ("counter", "steps that dispatched a decode"),
    "plan_rebuilds": ("counter", "plan/epoch rebuilds"),
    "fused_dispatches": ("counter", "fused jitted dispatches"),
    "token_flushes": ("counter", "deferred-token sync points"),
    "merge_bytes": ("counter", "cross-shard POR merge wire bytes"),
    "merge_rounds": ("counter", "cross-shard POR merge rounds"),
    "calibrations": ("counter", "CostModel.fit adoptions"),
    # speculation
    "spec_steps": ("counter", "speculative verify steps"),
    "spec_proposed": ("counter", "draft tokens proposed"),
    "spec_accepted": ("counter", "draft tokens accepted"),
    "spec_draft_stalls": ("counter", "draft growth stalled on pages"),
    # prefix cache
    "cache_hits": ("counter", "prefix-cache lookup hits"),
    "cache_misses": ("counter", "prefix-cache lookup misses"),
    "cache_hit_tokens": ("counter", "prompt tokens served from cache"),
    "cache_lookup_tokens": ("counter", "prompt tokens looked up"),
    "cache_evicted_nodes": ("counter", "cached nodes evicted"),
    "cache_evicted_pages": ("counter", "cached pages evicted"),
    # faults / degradation ladder (DESIGN §12)
    "faults_injected": ("counter", "injector seams fired"),
    "dispatch_failures": ("counter", "ResourceExhausted dispatches"),
    "dispatch_recoveries": ("counter", "degradation-ladder recoveries"),
    "replica_promotions": ("counter", "prefix replicas created"),
    "replica_demotions": ("counter", "prefix replicas dropped"),
    "nan_rows": ("counter", "rows quarantined for non-finite logits"),
    "callback_errors": ("counter", "user callbacks that raised"),
    "invariant_checks": ("counter", "engine.check() runs"),
    # gauges
    "pool_occupancy": ("gauge", "KV pool fraction in use"),
    "pool_free_pages": ("gauge", "KV pages free"),
    "backoff_pages": ("gauge", "admission-shrink ladder holdback"),
    "running": ("gauge", "requests in the decode batch"),
    "waiting": ("gauge", "requests queued"),
    "prefilling": ("gauge", "requests mid-prefill"),
    "cache_hit_rate": ("gauge", "cumulative prefix-cache hit rate"),
    "cache_resident_pages": ("gauge", "pages held as cache content"),
    "cache_resident_bytes": ("gauge", "bytes held as cache content"),
    "compile_count": ("gauge", "fused-step jit cache entries"),
    # latency histograms (seconds)
    "ttft_s": ("histogram", "submit -> first committed token"),
    "tpot_s": ("histogram", "per-request mean inter-token gap"),
    "e2e_s": ("histogram", "submit -> stream close"),
    "queue_wait_s": ("histogram", "submit -> first admission"),
    "prefill_chunk_s": ("histogram", "one chunked-prefill dispatch"),
    "dispatch_s": ("histogram", "decode dispatch (submit, per step)"),
    "flush_s": ("histogram", "flush_tokens device sync wait"),
    "step_s": ("histogram", "whole engine step wall time"),
    "plan_build_s": ("histogram", "plan/epoch rebuild wall time"),
    # sampled profiling (profile_every): blocked per-phase splits
    "profile_dispatch_s": ("histogram", "sampled: host submit phase"),
    "profile_device_s": ("histogram", "sampled: device execute wait"),
    "profile_host_s": ("histogram", "sampled: host prep before submit"),
}

# engine.stats key -> counter name (synced as monotone deltas each step)
ENGINE_STAT_COUNTERS: Dict[str, str] = {
    "steps": "decode_steps",
    "admitted": "requests_admitted",
    "preempted": "preemptions",
    "reclaimed": "reclaims",
    "prefill_tokens": "prefill_tokens",
    "recompute_tokens": "recompute_tokens",
    "prefill_chunks": "prefill_chunks",
    "prefill_stalls": "prefill_stalls",
    "cascade_groups": "cascade_groups",
    "cascade_shared_tokens": "cascade_shared_tokens",
    "cascade_suffix_tokens": "cascade_suffix_tokens",
    "cascade_batches": "cascade_batches",
    "replans": "plan_rebuilds",
    "fused_calls": "fused_dispatches",
    "token_flushes": "token_flushes",
    "calibrations": "calibrations",
    "spec_steps": "spec_steps",
    "spec_proposed": "spec_proposed",
    "spec_accepted": "spec_accepted",
    "spec_draft_stalls": "spec_draft_stalls",
    "cancelled": "requests_cancelled",
    "timed_out": "requests_timed_out",
    "failed": "requests_failed",
    "callback_errors": "callback_errors",
    "faults_injected": "faults_injected",
    "dispatch_failures": "dispatch_failures",
    "dispatch_recoveries": "dispatch_recoveries",
    "replica_promotions": "replica_promotions",
    "replica_demotions": "replica_demotions",
    "nan_rows": "nan_rows",
    "invariant_checks": "invariant_checks",
}

# cache.stats key -> counter name
CACHE_STAT_COUNTERS: Dict[str, str] = {
    "hits": "cache_hits",
    "misses": "cache_misses",
    "hit_tokens": "cache_hit_tokens",
    "lookup_tokens": "cache_lookup_tokens",
    "evicted_nodes": "cache_evicted_nodes",
    "evicted_pages": "cache_evicted_pages",
}


class TraceSink:
    """Receives every finished trace event (a Chrome trace-event dict).

    The default :class:`MemoryTraceSink` buffers for ``export_trace``;
    inject a custom sink to stream events elsewhere (a file, a test
    assertion, a live UI).  ``emit`` must not raise into the engine.
    """

    def emit(self, event: Dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError


class MemoryTraceSink(TraceSink):
    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)


class Telemetry:
    """Metrics + tracing + profiling policy for one :class:`DecodeEngine`.

    Construct directly (``DecodeEngine(telemetry=Telemetry(...))``) or
    let the engine build a default one with ``telemetry=True``.  The
    engine binds its injectable clock at construction so span
    timestamps share the deadline clock (fake clocks give
    deterministic traces).
    """

    def __init__(self, profile_every: int = 0,
                 sink: Optional[TraceSink] = None, clock=None):
        if profile_every < 0:
            raise ValueError(
                f"profile_every must be >= 0, got {profile_every}")
        self.profile_every = int(profile_every)
        self.sink = sink if sink is not None else MemoryTraceSink()
        self.clock = clock          # engine calls bind_clock if None
        self.metrics = metrics_mod.MetricsRegistry()
        for name, (kind, help_) in METRIC_CATALOG.items():
            getattr(self.metrics, kind)(name, help=help_)
        self._t0: Optional[float] = None
        # per-track open-span stacks: track -> [(name, ts, args)]
        self._open: Dict[Any, List[Tuple[str, float, Optional[Dict]]]] = {}
        # last synced cumulative stats, per source ("engine", "cache")
        self._seen: Dict[str, Dict[str, float]] = {}
        self._meta_emitted: set = set()

    # ---- clock ----------------------------------------------------- #
    def bind_clock(self, clock) -> None:
        """Adopt the engine's clock unless one was injected directly."""
        if self.clock is None:
            self.clock = clock

    def _now(self) -> float:
        return self.clock() if self.clock is not None else 0.0

    def _ts_us(self, t: float) -> float:
        if self._t0 is None:
            self._t0 = t
        return (t - self._t0) * 1e6

    # ---- spans ------------------------------------------------------ #
    def _track(self, track) -> Tuple[int, int]:
        """(pid, tid) for a track key: ENGINE or a request id."""
        if track == ENGINE:
            return _PID_ENGINE, 0
        return _PID_REQUESTS, int(track)

    def _emit_meta(self, pid: int, tid: int) -> None:
        if pid not in self._meta_emitted:
            self._meta_emitted.add(pid)
            name = "engine" if pid == _PID_ENGINE else "requests"
            self.sink.emit({"ph": "M", "pid": pid, "tid": 0, "ts": 0,
                            "name": "process_name",
                            "args": {"name": name}})
        if (pid, tid) not in self._meta_emitted and pid == _PID_REQUESTS:
            self._meta_emitted.add((pid, tid))
            self.sink.emit({"ph": "M", "pid": pid, "tid": tid, "ts": 0,
                            "name": "thread_name",
                            "args": {"name": f"request {tid}"}})

    def begin(self, name: str, track=ENGINE,
              args: Optional[Dict] = None) -> None:
        """Open a span; close it with :meth:`end` (LIFO per track)."""
        self._open.setdefault(track, []).append((name, self._now(), args))

    def end(self, track=ENGINE, args: Optional[Dict] = None) -> None:
        """Close the innermost open span on ``track`` as an "X" event."""
        stack = self._open.get(track)
        if not stack:
            return
        name, t_start, a0 = stack.pop()
        merged = dict(a0 or {})
        if args:
            merged.update(args)
        self.complete(name, t_start, self._now(), track=track,
                      args=merged or None)

    def end_all(self, track) -> None:
        """Close every open span on ``track`` (terminal transitions)."""
        while self._open.get(track):
            self.end(track)

    def complete(self, name: str, t_start: float, t_end: float,
                 track=ENGINE, args: Optional[Dict] = None) -> None:
        """Emit a finished span from explicit clock readings."""
        pid, tid = self._track(track)
        self._emit_meta(pid, tid)
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": self._ts_us(t_start),
              "dur": max(0.0, self._ts_us(t_end) - self._ts_us(t_start)),
              "cat": "engine" if pid == _PID_ENGINE else "request"}
        if args:
            ev["args"] = args
        self.sink.emit(ev)

    def instant(self, name: str, track=ENGINE,
                args: Optional[Dict] = None) -> None:
        pid, tid = self._track(track)
        self._emit_meta(pid, tid)
        ev = {"name": name, "ph": "i", "pid": pid, "tid": tid,
              "ts": self._ts_us(self._now()), "s": "t",
              "cat": "engine" if pid == _PID_ENGINE else "request"}
        if args:
            ev["args"] = args
        self.sink.emit(ev)

    # ---- profiling policy ------------------------------------------- #
    def should_profile(self, step_index: int) -> bool:
        """True on steps the fused path should block and phase-split."""
        return (self.profile_every > 0
                and step_index % self.profile_every == 0)

    # ---- stat syncing ----------------------------------------------- #
    def sync_counters(self, source: str,
                      stats: Dict[str, float],
                      mapping: Dict[str, str]) -> None:
        """Fold a cumulative stats dict into registry counters.

        Each call increments by the delta since the previous call for
        the same ``source`` — callers hand over the SAME cumulative
        dict every time and the registry stays monotone regardless of
        how many readers poll it afterwards.
        """
        seen = self._seen.setdefault(source, {})
        for key, name in mapping.items():
            cur = float(stats.get(key, 0))
            d = cur - seen.get(key, 0.0)
            if d > 0:
                self.metrics[name].inc(d)
            seen[key] = cur

    def set_gauges(self, values: Dict[str, float]) -> None:
        for name, v in values.items():
            self.metrics[name].set(v)

    def observe(self, name: str, v: float) -> None:
        self.metrics[name].observe(v)

    # ---- export ------------------------------------------------------ #
    def trace_events(self) -> List[Dict[str, Any]]:
        """Finished events (open spans are excluded until ended)."""
        if isinstance(self.sink, MemoryTraceSink):
            return list(self.sink.events)
        raise TypeError(
            "trace_events()/export_trace() need the default "
            "MemoryTraceSink; a custom sink owns its own events")

    def export_trace(self, path: str) -> None:
        """Write Chrome trace-event JSON (Perfetto-loadable)."""
        doc = {"traceEvents": self.trace_events(),
               "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f, indent=None)

    def export_metrics(self, path: str,
                       extra: Optional[Dict] = None) -> None:
        """Write the registry snapshot as schema-tagged JSON."""
        doc = {"schema": METRICS_SCHEMA,
               "metrics": json.loads(self.metrics.to_json())}
        if extra:
            doc.update(extra)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
