"""Decode-serving mesh builders.

The serving mesh is always 2-D ``(data, model)``: KV pages (and so plan
subtasks) shard over ``data``, KV heads over ``model`` (TP-aligned with
``launch.sharding``'s param scheme).  Unlike ``launch.mesh`` these
builders make *plain* meshes (no GSPMD auto axis types): the sharded
decode step is traced manually under ``shard_map``, which owns both
axes explicitly.

Everything runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the pattern the
launch tests use); a ``1x1`` mesh exercises the full SPMD code path on
a single device.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def parse_mesh(spec: str) -> Tuple[int, int]:
    """``"DxM"`` -> ``(data, model)`` sizes (e.g. ``"2x2"``)."""
    try:
        d, m = spec.lower().split("x")
        d, m = int(d), int(m)
    except ValueError:
        raise ValueError(f"mesh spec must look like '2x1', got {spec!r}")
    if d < 1 or m < 1:
        raise ValueError(f"mesh sizes must be >= 1, got {spec!r}")
    return d, m


def decode_mesh(data: int = 1, model: int = 1):
    """Build a ``(data, model)`` mesh over the first ``data*model``
    devices.  ``data`` must be a power of two (the cross-device POR
    merge is a recursive-doubling butterfly)."""
    import jax

    if data & (data - 1):
        raise ValueError(f"data axis must be a power of two, got {data}")
    n = data * model
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh {data}x{model} needs {n} devices, have {len(devs)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before jax initialises)")
    return jax.sharding.Mesh(
        np.array(devs[:n]).reshape(data, model), ("data", "model"))


def mesh_shape(mesh) -> Tuple[int, int]:
    return mesh.shape["data"], mesh.shape["model"]
