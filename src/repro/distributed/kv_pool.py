"""Distributed paged KV pool: pages over ``data``, heads over ``model``.

The logical pool is still ONE array pair ``k/v`` with the PagedKVPool
layout, but its page-row axis is laid out as ``D`` contiguous
per-shard blocks of ``pages_per_shard + 1`` rows — the last row of
every block is that shard's **trash page** (the write sink for padded
batch rows and non-owner tail writes of the sharded fused step).  A
``NamedSharding`` places block ``d`` on the mesh's data-row ``d`` and
splits the KV-head axis over ``model`` (``launch.sharding.
paged_pool_spec`` — the same head axis the TP param rules shard), so
under ``shard_map`` each device sees exactly its ``(pages_d,
heads_m)`` slab and plans index it with shard-local page rows.

Allocation goes through one :class:`ShardedPageAllocator` facade over
``D`` per-shard :class:`~repro.serving.kv_cache.PageAllocator`\\ s — the
single-device invariants (refcounts, free-list partition, ``check()``,
watermarks) hold *per shard*.  Placement is deterministic: a node's
pages stay on one shard until its ``seq_split_pages`` quota is
reached, then continue on the next-freest shard — a long shared prefix
therefore lands as contiguous page runs on several shards, which is
exactly the sequence split the plan partitioner turns into a
cross-device POR merge.

Host-side prefill keeps using the global array (gathers/scatters over
shard boundaries lower to collectives under GSPMD); ``canonicalize()``
re-pins the arrays to the pool sharding at plan-epoch boundaries so
the donated fused step always starts from the canonical layout.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from ..launch.sharding import paged_pool_spec
from ..serving.kv_cache import PageAllocator, PagedKVPool


class ShardedPageAllocator:
    """Facade over per-shard allocators with a placement policy.

    Page ids are global *rows* into the pool array: row ``g`` lives on
    shard ``g // stride`` as local row ``g % stride`` where ``stride =
    pages_per_shard + 1`` (local row ``pages_per_shard`` is the shard's
    trash page and is never allocated).
    """

    def __init__(self, num_shards: int, pages_per_shard: int,
                 seq_split_pages: int = 0):
        self.num_shards = num_shards
        self.pages_per_shard = pages_per_shard
        self.stride = pages_per_shard + 1
        # quota of consecutive pages one affinity key keeps on a shard
        # before placement moves on (0 = only move when the shard fills)
        self.seq_split_pages = int(seq_split_pages)
        self.shards = [PageAllocator(pages_per_shard)
                       for _ in range(num_shards)]
        # hint (node id) -> [shard, pages placed there since last move,
        # live refcount over the hint's rows].  Insertion order doubles
        # as LRU order: entries are re-appended on every use, and the
        # size bound only evicts entries whose live count is zero — a
        # FIFO pop could drop a LIVE node's entry, resetting its
        # seq_split_pages quota and scattering later growth.
        self._affinity: Dict[int, List[int]] = {}
        self._row_hint: Dict[int, int] = {}     # global row -> hint

    # -- id mapping ---------------------------------------------------- #
    def shard_of(self, row: int) -> int:
        return row // self.stride

    def local_of(self, row: int) -> int:
        return row % self.stride

    # -- aggregate accounting (engine-facing API) ---------------------- #
    @property
    def num_pages(self) -> int:
        return self.num_shards * self.pages_per_shard

    @property
    def num_free(self) -> int:
        return sum(s.num_free for s in self.shards)

    @property
    def num_used(self) -> int:
        return sum(s.num_used for s in self.shards)

    @property
    def peak_used(self) -> int:
        return sum(s.peak_used for s in self.shards)

    @property
    def total_allocs(self) -> int:
        return sum(s.total_allocs for s in self.shards)

    def occupancy(self) -> float:
        return self.num_used / max(self.num_pages, 1)

    def shard_occupancy(self) -> List[float]:
        return [s.occupancy() for s in self.shards]

    # -- alloc / release ------------------------------------------------ #
    def _touch(self, hint: int) -> None:
        """LRU-touch: re-append the entry so the size bound sees it last."""
        self._affinity[hint] = self._affinity.pop(hint)

    def _pick(self, hint: Optional[int]) -> int:
        if hint is not None:
            st = self._affinity.get(hint)
            if (st is not None and self.shards[st[0]].num_free > 0
                    and (self.seq_split_pages <= 0
                         or st[1] < self.seq_split_pages)):
                self._touch(hint)
                return st[0]
        # next-freest shard, deterministic ties (lowest index); when an
        # affinity key moves on, exclude its current shard so a reached
        # quota really splits the run even if that shard is the freest
        prev = self._affinity.get(hint, [None, 0])[0] if hint is not None \
            else None
        best, best_free = -1, -1
        for i, s in enumerate(self.shards):
            if i == prev and any(j != prev and x.num_free > 0
                                 for j, x in enumerate(self.shards)):
                continue
            if s.num_free > best_free:
                best, best_free = i, s.num_free
        if best_free <= 0:
            raise MemoryError(
                f"KV pool exhausted: need 1, have {self.num_free}")
        if hint is not None:
            st = self._affinity.get(hint)
            if st is None:
                self._affinity[hint] = [best, 0, 0]
            else:
                st[0], st[1] = best, 0
                self._touch(hint)
            self._trim()
        return best

    def _trim(self) -> None:
        # bound on stale node ids: evict oldest entry with NO live pages
        # (live entries must keep their quota state — see _affinity)
        while len(self._affinity) > 8192:
            dead = next((k for k, v in self._affinity.items() if v[2] == 0),
                        None)
            if dead is None:
                return
            del self._affinity[dead]

    def alloc(self, n: int, hint: Optional[int] = None) -> List[int]:
        if n > self.num_free:
            raise MemoryError(
                f"KV pool exhausted: need {n}, have {self.num_free}")
        rows = []
        for _ in range(n):
            sh = self._pick(hint)
            local = self.shards[sh].alloc(1)[0]
            row = sh * self.stride + local
            if hint is not None:
                st = self._affinity[hint]
                st[1] += 1
                st[2] += 1
                self._row_hint[row] = hint
            rows.append(row)
        return rows

    def alloc_replicas(self, n: int,
                       hint: Optional[int] = None) -> Dict[int, List[int]]:
        """Allocate ``n`` pages on EVERY shard (replication placement).

        Returns ``{shard: [global rows]}`` with one ``n``-page run per
        shard.  All-or-nothing: raises ``MemoryError`` without touching
        any shard if one of them cannot fit ``n`` pages.  The affinity
        entry is pinned to the freest shard (the replica the scheduler
        treats as primary) and its live count covers ALL replica rows,
        so the entry survives the size bound while any replica lives.
        """
        if any(s.num_free < n for s in self.shards):
            raise MemoryError(
                f"KV pool exhausted for replication: need {n} pages on "
                f"each of {self.num_shards} shards, free per shard = "
                f"{[s.num_free for s in self.shards]}")
        primary = max(range(self.num_shards),
                      key=lambda i: (self.shards[i].num_free, -i))
        out: Dict[int, List[int]] = {}
        for sh in range(self.num_shards):
            locals_ = self.shards[sh].alloc(n)
            out[sh] = [sh * self.stride + lo for lo in locals_]
        if hint is not None:
            st = self._affinity.get(hint)
            if st is None:
                st = self._affinity[hint] = [primary, 0, 0]
            else:
                st[0] = primary
                self._touch(hint)
            for rows in out.values():
                for g in rows:
                    self._row_hint[g] = hint
                    st[2] += 1
            self._trim()
        return out

    def _by_shard(self, rows: List[int]) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for g in rows:
            sh, local = self.shard_of(g), self.local_of(g)
            if sh >= self.num_shards or local >= self.pages_per_shard:
                raise ValueError(f"page row {g} outside the pool")
            out.setdefault(sh, []).append(local)
        return out

    def retain(self, rows: List[int]) -> None:
        for sh, locals_ in self._by_shard(rows).items():
            self.shards[sh].retain(locals_)
        for g in rows:
            h = self._row_hint.get(g)
            if h is not None and h in self._affinity:
                self._affinity[h][2] += 1

    def release(self, rows: List[int]) -> None:
        for sh, locals_ in self._by_shard(rows).items():
            self.shards[sh].release(locals_)
        for g in rows:
            h = self._row_hint.get(g)
            if h is None:
                continue
            if self.local_of(g) not in self.shards[self.shard_of(g)]._refs:
                del self._row_hint[g]     # row fully freed
            st = self._affinity.get(h)
            if st is not None:
                st[2] = max(0, st[2] - 1)

    def used_page_ids(self) -> Dict[int, int]:
        """Allocated GLOBAL rows -> refcount across every shard (the
        engine's self-check compares this with forest page ownership)."""
        out: Dict[int, int] = {}
        for sh, s in enumerate(self.shards):
            for local, refs in s.used_page_ids().items():
                out[sh * self.stride + local] = refs
        return out

    def check(self) -> None:
        """Per-shard structural invariants (tests call after workloads)."""
        for s in self.shards:
            s.check()


class ShardedKVPool(PagedKVPool):
    """Mesh-sharded paged pool; same engine-facing API as PagedKVPool."""

    def __init__(self, n_layers: int, num_pages: int, page_size: int,
                 n_kv: int, head_dim: int, *, mesh,
                 seq_split_pages: int = 0, dtype=jnp.float32):
        D = mesh.shape["data"]
        per_shard = num_pages // D
        if per_shard < 1 or num_pages % D:
            raise ValueError(
                f"num_pages={num_pages} must be a positive multiple of "
                f"the data axis ({D}): silent truncation would change "
                f"eviction behaviour in capacity-tuned runs")
        self.mesh = mesh
        self.n_layers = n_layers
        self.num_pages = D * per_shard          # allocatable pages
        self.page_size = page_size
        self.allocator = ShardedPageAllocator(D, per_shard, seq_split_pages)
        rows = D * self.allocator.stride
        self.sharding = jax.sharding.NamedSharding(
            mesh, paged_pool_spec(mesh, n_kv))
        self.k = jax.device_put(
            jnp.zeros((n_layers, rows, page_size, n_kv, head_dim), dtype),
            self.sharding)
        self.v = jax.device_put(jnp.zeros_like(self.k), self.sharding)

    @property
    def num_shards(self) -> int:
        return self.allocator.num_shards

    @property
    def page_stride(self) -> int:
        return self.allocator.stride

    @property
    def local_trash(self) -> int:
        """Shard-local row id of every shard's trash page."""
        return self.allocator.pages_per_shard

    @property
    def trash_page(self) -> int:
        """Global row of shard 0's trash page (single-device API compat;
        the sharded step always uses per-shard local trash rows)."""
        return self.local_trash

    def shard_of(self, row: int) -> int:
        return self.allocator.shard_of(row)

    def local_of(self, row: int) -> int:
        return self.allocator.local_of(row)

    def shard_occupancy(self) -> List[float]:
        return self.allocator.shard_occupancy()

    def canonicalize(self) -> None:
        """Re-pin k/v to the pool sharding (host-side prefill scatters
        may have let GSPMD drift the layout); called at plan epochs so
        the donated SPMD step starts canonical."""
        self.k = jax.device_put(self.k, self.sharding)
        self.v = jax.device_put(self.v, self.sharding)
