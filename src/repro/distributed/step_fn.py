"""SPMD fused decode step: one jitted dispatch per token, per mesh.

The sharded sibling of ``serving/step_fn.py``: the whole decode step —
layer scan, KV tail writes, per-shard backend partials, the
cross-device POR merge, head-TP output projection, FFN/Mamba, unembed,
sampling — traces as ONE donated program under ``shard_map`` over a
``(data, model)`` mesh:

* **data axis** — KV pages (and so plan subtasks) are sharded; every
  device runs its own shard's plan over its local pool block and the
  per-query partials of rows whose KV spans shards are packed and
  merged with the psum/all_gather-free sparse POR butterfly
  (``kernels.por.por_subgroup_merge`` — one packed ppermute per round
  over the minimal contributing subgroup).  Rows served entirely by
  replicated nodes are computed bitwise identically on every shard and
  never cross the wire; when no row needs merging the collective is
  absent from the compiled program.  A node sequence-split across data
  shards is merged by exactly the same reduction.
* **model axis** — KV heads are sharded (TP-aligned): each device
  slices its head block out of the (replicated-weight) q/k/v
  projections, attends with its local heads, and the output
  projection is a partial matmul ``psum``-reduced over ``model`` —
  the standard TP epilogue.
* everything head/page-free (embedding, FFN/MoE, Mamba state, norm,
  unembed, sampling) is computed replicated on every device, so the
  sampled tokens are bitwise identical mesh-wide and the ``P()``
  output spec is honest.  Sampling is safe to replicate because the
  sampler derives per-row keys with ``fold_in`` — draws are
  independent of mesh shape and bucket padding alike.

Tail pages: each batch row's growing page lives on exactly one data
shard; non-owner shards scatter the row's new KV into their local
**trash page** and contribute the POR identity ``(o=0, m=-inf, l=0)``
to the tail merge, so the butterfly stays shape-uniform.

Per-epoch inputs mirror the single-device ``StepBase`` but carry the
per-shard tail layout stacked on a leading ``data``-sharded axis
(:class:`ShardedStepBase`); per-shard prepared plan arrays are stacked
the same way by the engine (``core.plan.build_sharded_plan`` buckets
all shards to common shapes precisely so this stacking is
rectangular).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..kernels import ops, por as por_mod, ref as ref_mod
from ..launch.sharding import paged_pool_spec
from ..models import layers as L
from ..models import mamba as M
from ..models import transformer as T
from ..serving import sampler
from ..serving.step_fn import StepState, _silence_donation_warning

MASK_VALUE = ref_mod.MASK_VALUE


class ShardedStepBase(NamedTuple):
    """Per-epoch device inputs for the SPMD step.

    Replicated fields are ``(B,)``; tail-layout fields are stacked
    ``(D, B)`` and sharded over ``data`` (each shard reads its row).
    """

    row_valid: jnp.ndarray   # (B,) bool — padded bucket rows are False
    q_pos0: jnp.ndarray      # (B,) int32 query position at delta=0 (-1 pads)
    tail_page: jnp.ndarray   # (D, B) int32 LOCAL tail page row (else trash)
    tail_base: jnp.ndarray   # (B,) int32 abs position of the page's slot 0
    tail_off0: jnp.ndarray   # (B,) int32 in-page slot written at delta=0
    tail_owner: jnp.ndarray  # (D, B) bool — shard holds the row's tail
    #                          (one-hot per row; ALL shards for a
    #                          replicated leaf, whose tail page is that
    #                          shard's local replica)
    # sparse cross-shard merge (Bm is in the jit signature; Bm=0 skips
    # the collective entirely — fully-replicated epochs pay no wire):
    merge_gather: jnp.ndarray   # (Bm,) int32 rows to pack (pad 0)
    merge_scatter: jnp.ndarray  # (Bm,) int32 scatter target (pad B -> drop)
    contrib: jnp.ndarray        # (D,) bool — shards with local partials


def make_sharded_step_fn(cfg: ModelConfig, backend,
                         windows: Tuple[int, ...], temperature: float,
                         mesh):
    """Build the SPMD fused decode step for one engine configuration.

    Same signature as the single-device step —

        ``fn(params, state, tokens, key, base, delta, prepared)
        -> (tokens', key', state')``

    — but ``state.pool_k/v`` are mesh-sharded (pages -> ``data``, heads
    -> ``model``), ``base`` is a :class:`ShardedStepBase`, and each
    element of ``prepared`` is the backend's prepared plan arrays
    stacked ``(D, ...)`` over data shards.  ``backend`` must be
    ``shardable`` (registry flag).
    """
    _silence_donation_warning()
    D = mesh.shape["data"]
    Mx = mesh.shape["model"]
    win_slot = {w: i for i, w in enumerate(windows)}
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    heads_sharded = Mx > 1
    if heads_sharded and (hq % Mx or hkv % Mx):
        raise ValueError(
            f"model axis {Mx} must divide heads ({hq} q / {hkv} kv)")
    hq_loc = hq // Mx if heads_sharded else hq
    hkv_loc = hkv // Mx if heads_sharded else hkv

    def local_step(params, state: StepState, tokens: jnp.ndarray, key,
                   base: ShardedStepBase, delta, prepared: Tuple[Any, ...]):
        B = tokens.shape[0]
        m_idx = jax.lax.axis_index("model")
        # squeeze this shard's row off the data-stacked fields
        tail_page = base.tail_page[0]
        tail_owner = base.tail_owner[0]
        prepared = jax.tree.map(lambda a: a[0], prepared)

        dlt = jnp.asarray(delta, jnp.int32) * base.row_valid.astype(jnp.int32)
        q_pos = base.q_pos0 + dlt
        tail_off = base.tail_off0 + dlt
        advanced = tuple(backend.advance_fn(p, delta) for p in prepared)
        x = T._embed(params, cfg, tokens[:, None], q_pos[:, None])  # (B,1,d)

        def head_slice(a, blk, axis):
            if not heads_sharded:
                return a
            return jax.lax.dynamic_slice_in_dim(a, m_idx * blk, blk, axis)

        def body(c, kind, p, la, lm):
            x, pool_k, pool_v, conv_all, ssm_all = c
            h = L.apply_norm(p["ln"], x, cfg)
            if kind.mixer in ("attn", "attn_local"):
                w = cfg.sliding_window if kind.mixer == "attn_local" else 0
                q, k_new, v_new = L.attn_project(p["attn"], cfg, h,
                                                 q_pos[:, None])
                # this device's head block of the (replicated) projection
                k_loc = head_slice(k_new[:, 0], hkv_loc, 1)
                v_loc = head_slice(v_new[:, 0], hkv_loc, 1)
                q_loc = head_slice(q[:, 0], hq_loc, 1)     # (B, h_loc, hd)
                # tail write: owners hit the row's tail slot, everyone
                # else this shard's trash page
                pool_k = pool_k.at[la, tail_page, tail_off].set(
                    k_loc.astype(pool_k.dtype))
                pool_v = pool_v.at[la, tail_page, tail_off].set(
                    v_loc.astype(pool_v.dtype))
                k_pool, v_pool = pool_k[la], pool_v[la]
                # frozen-plan partials over this shard's pages + heads
                o_f, m_f, l_f = backend.partials_arrays_fn(
                    q_loc, k_pool, v_pool, advanced[win_slot[w]],
                    num_queries=B, window=w)
                # tail partials; non-owners contribute the POR identity
                kt = k_pool[tail_page]
                vt = v_pool[tail_page]
                o_t, m_t, l_t = ops.single_page_attention(
                    q_loc, kt, vt, base.tail_base, q_pos, window=w)
                own = tail_owner
                m_t = jnp.where(own[:, None], m_t, MASK_VALUE)
                l_t = jnp.where(own[:, None], l_t, 0.0)
                o_t = jnp.where(own[:, None, None], o_t, 0.0)
                o, m, l = ref_mod.por_ref(o_f, m_f, l_f, o_t, m_t, l_t)
                # sparse cross-device sequence merge: only rows whose KV
                # actually spans shards are packed and sent through the
                # subgroup butterfly; fully-replicated / single-shard
                # rows were computed bitwise identically everywhere and
                # skip the wire.  Bm == 0 drops the collective from the
                # program altogether.
                Bm = base.merge_gather.shape[0]
                if D > 1 and Bm > 0:
                    with jax.named_scope("codec.por_merge"):
                        gi = base.merge_gather
                        og, mg, lg = por_mod.por_subgroup_merge(
                            o[gi], m[gi], l[gi], "data", D, base.contrib)
                        si = base.merge_scatter
                        o = o.at[si].set(og, mode="drop")
                        m = m.at[si].set(mg, mode="drop")
                        l = l.at[si].set(lg, mode="drop")
                o_flat = o.astype(q_loc.dtype).reshape(B, 1, hq_loc * hd)
                if heads_sharded:
                    # TP epilogue: partial output projection, psum(model)
                    w_rows = jax.lax.dynamic_slice_in_dim(
                        p["attn"]["wo"]["w"], m_idx * hq_loc * hd,
                        hq_loc * hd, 0)
                    y = jax.lax.psum(o_flat @ w_rows, "model")
                else:
                    y = L.dense(p["attn"]["wo"], o_flat)
                x = x + y
            elif kind.mixer == "mamba":
                y, (conv_n, ssm_n) = M.mamba_decode(
                    p["mamba"], cfg, h, conv_all[lm], ssm_all[lm])
                conv_all = conv_all.at[lm].set(conv_n)
                ssm_all = ssm_all.at[lm].set(ssm_n)
                x = x + y
            x, _ = L.apply_ffn_block(p, cfg, kind.ffn, x)
            return (x, pool_k, pool_v, conv_all, ssm_all)

        x, pool_k, pool_v, conv_all, ssm_all = T.scan_layer_stack(
            cfg, params, body,
            (x, state.pool_k, state.pool_v, state.conv, state.ssm))
        with jax.named_scope("codec.sample"):
            logits = T._unembed(params, cfg, x)[:, 0]       # (B, V)
            key, sk = jax.random.split(key)
            toks = sampler.sample(logits, sk, temperature)
        return toks, key, StepState(pool_k, pool_v, conv_all, ssm_all)

    pool_spec = paged_pool_spec(mesh, hkv)
    state_spec = StepState(pool_spec, pool_spec, P(), P())
    base_spec = ShardedStepBase(P(), P(), P("data"), P(), P(), P("data"),
                                P(), P(), P())
    fn = shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), state_spec, P(), P(), base_spec, P(), P("data")),
        out_specs=(P(), P(), state_spec),
        check_rep=False)
    return jax.jit(fn, donate_argnums=(1,))
