"""Multi-device SPMD serving: sharded KV pool + sharded fused step.

The layer between the plan compiler and the kernels that lets one
engine serve prefixes and batches larger than a single device's HBM
(DESIGN.md §9):

* ``mesh.py``     — decode mesh builders (``data`` x ``model`` axes);
* ``kv_pool.py``  — ``ShardedKVPool``: paged KV partitioned pages ->
  ``data``, heads -> ``model``, with per-shard allocator invariants;
* ``step_fn.py``  — the fused decode step traced under ``shard_map``:
  per-shard plan partials, cross-device POR butterfly merge, head-TP
  output projection, replicated sampling.
"""

from .kv_pool import ShardedKVPool, ShardedPageAllocator  # noqa: F401
from .mesh import decode_mesh, parse_mesh                 # noqa: F401
from .step_fn import ShardedStepBase, make_sharded_step_fn  # noqa: F401
