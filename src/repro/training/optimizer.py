"""Optimizers (optax-style init/update pairs, no external deps).

AdamW for normal archs; Adafactor (factored second moment, no first
moment) for the trillion-param MoE where full Adam state would exceed the
512-chip HBM budget.  Plus: global-norm clipping, cosine schedule with
warmup, and an int8 gradient-compression transform (error feedback) for
the cross-pod data-parallel all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(np.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def adamw(schedule: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        lr = schedule(count)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), m_new.astype(state_dtype), \
                v_new.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def adafactor(schedule: Callable, decay: float = 0.8, eps: float = 1e-30,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern): O(n+m) state for
    an (n, m) matrix — the only way 1T params fit the 512-chip budget."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        lr = schedule(count)
        beta = 1.0 - count.astype(jnp.float32) ** (-decay)

        def one(g, slot, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * slot["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * slot["vc"] + (1 - beta) * g2.mean(-2)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                step = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                            + 1e-9)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                step = g / (jnp.sqrt(v) + 1e-9)
                new_slot = {"v": v}
            step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), new_slot

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        flat_p = jax.tree.leaves(params)
        ups, slots = [], []
        for g, s, p in zip(flat_g, flat_s, flat_p):
            u, ns = one(g, s, p)
            ups.append(u)
            slots.append(ns)
        return (jax.tree.unflatten(treedef, ups),
                {"slots": jax.tree.unflatten(treedef, slots), "count": count})

    return Optimizer(init, update)


def make_optimizer(name: str, schedule: Callable, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "adafactor":
        return adafactor(schedule, **kw)
    raise ValueError(name)


# --------------------------------------------------------------------- #
# gradient compression (cross-pod DP all-reduce)
# --------------------------------------------------------------------- #
def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize g+err to int8 with per-tensor scale; returns (q, scale,
    new_err).  Used before the cross-pod (DCN) all-reduce: 4x fewer bytes
    on the slowest link; error feedback keeps the scheme unbiased over
    steps."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
