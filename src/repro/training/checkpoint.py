"""Distributed checkpointing: per-host shard files + manifest, atomic publish.

Layout::

    <dir>/step_000123/shard_00003.npz      one file per host
    <dir>/step_000123/MANIFEST.json        written LAST (atomic publish)

A step directory without a manifest is an incomplete/aborted save and is
ignored by ``latest_step`` — so a preemption mid-save can never corrupt
the restore path.  Each host writes only its addressable shard of every
array (``host_slice``); restore re-assembles (or re-shards onto a new
mesh — elastic restart after losing hosts reuses the same files).

On this CPU container "hosts" are simulated by slicing the leading axis;
on a real multi-host TPU pod the same code path uses
``jax.process_index()`` and addressable shards.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    *, num_shards: int = 1, keep: int = 3,
                    extra: Optional[Dict] = None) -> str:
    """Save ``tree`` under ``ckpt_dir/step_NNNNNN``, atomically."""
    os.makedirs(ckpt_dir, exist_ok=True)
    step_dir = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp_dir = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    leaves, _ = _flatten(tree)

    manifest = {"step": step, "num_shards": num_shards,
                "time": time.time(), "extra": extra or {},
                "arrays": {}}
    shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(num_shards)]
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        manifest["arrays"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        if arr.ndim == 0 or num_shards == 1 or arr.shape[0] < num_shards:
            shards[0][key] = arr           # small/replicated: shard 0 owns it
            manifest["arrays"][key]["sharded"] = False
        else:
            manifest["arrays"][key]["sharded"] = True
            splits = np.array_split(arr, num_shards, axis=0)
            for s, piece in enumerate(splits):
                shards[s][key] = piece
    for s, shard in enumerate(shards):
        np.savez(os.path.join(tmp_dir, f"shard_{s:05d}.npz"), **shard)
    # manifest last: its presence marks the checkpoint complete
    with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:06d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "MANIFEST.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like: PyTree,
                    ) -> Tuple[PyTree, Dict]:
    """Restore a pytree with the structure of ``like`` from ``step``."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)
    files = [np.load(os.path.join(step_dir, f"shard_{s:05d}.npz"))
             for s in range(manifest["num_shards"])]
    leaves, treedef = _flatten(like)
    restored = []
    for key, leaf in leaves:
        meta = manifest["arrays"][key]
        if meta["sharded"]:
            arr = np.concatenate([f[key] for f in files if key in f.files],
                                 axis=0)
        else:
            arr = files[0][key]
        assert list(arr.shape) == meta["shape"], (key, arr.shape, meta)
        restored.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest


def load_latest(ckpt_dir: str, like: PyTree) -> Optional[Tuple[int, PyTree, Dict]]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, manifest = load_checkpoint(ckpt_dir, step, like)
    return step, tree, manifest
