from . import checkpoint, data, optimizer, trainer  # noqa: F401
