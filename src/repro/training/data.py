"""Deterministic, shardable synthetic data pipeline.

Every (step, dp_rank) pair maps to a unique counter-mode PRNG stream, so

* resume after preemption is exact: the iterator's only state is ``step``;
* elastic re-sharding is exact: rank r of world W draws rows
  ``[r*B/W, (r+1)*B/W)`` of the *global* batch, so changing W re-slices
  the same global stream rather than changing the data;
* no host coordination is needed — each host computes its slice locally.

The token distribution is a Zipf-like categorical with a per-sequence
shift so batches are not degenerate (useful for loss-goes-down checks).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Deterministic LM token stream: next-token targets = shifted input."""

    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_world: int = 1,
                 start_step: int = 0):
        assert cfg.global_batch % dp_world == 0, (cfg.global_batch, dp_world)
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_world = dp_world
        self.step = start_step

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.dp_world

    def _rows(self, step: int) -> Tuple[int, int]:
        lo = self.dp_rank * self.local_batch
        return lo, lo + self.local_batch

    def global_row(self, step: int, row: int) -> np.ndarray:
        """One global-batch row — the unit of determinism."""
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[step, row, 0, 0]))
        # Zipf-ish categorical over a row-dependent permutation offset
        ranks = rng.integers(1, 1024, size=cfg.seq_len + 1)
        toks = (ranks * ranks + row) % cfg.vocab_size
        return toks.astype(np.int32)

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self._rows(step)
        rows = np.stack([self.global_row(step, r) for r in range(lo, hi)])
        return rows[:, :-1], rows[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        b = self.batch(self.step)
        self.step += 1
        return b

    # checkpointable state --------------------------------------------- #
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])
