"""Training step builder: loss, microbatch accumulation, clip, update.

``make_train_step`` returns a pure ``train_step(state, batch)`` suitable
for ``jax.jit`` under a mesh — all distribution is expressed through
input shardings (GSPMD); the step itself is mesh-agnostic.  The same
function is what the multi-pod dry-run lowers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as T
from .optimizer import Optimizer, clip_by_global_norm

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: PyTree
    opt_state: PyTree


def init_state(cfg: ModelConfig, optimizer: Optimizer, key) -> TrainState:
    params = T.init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params))


def abstract_state(cfg: ModelConfig, optimizer: Optimizer) -> TrainState:
    """ShapeDtypeStruct pytree of a TrainState (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda k: init_state(cfg, optimizer, k), jax.random.PRNGKey(0))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE. logits: (B, T, V) f32; labels: (B, T) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def cross_entropy_onehot(logits: jnp.ndarray,
                         labels: jnp.ndarray) -> jnp.ndarray:
    """Vocab-sharding-friendly CE (beyond-paper perf path).

    ``take_along_axis`` on a vocab-sharded logits tensor makes GSPMD
    all-gather the full (B, T, V) array; the one-hot contraction keeps
    the vocab axis sharded end-to-end — the gather becomes a (B, T)
    partial-sum all-reduce.  Numerically identical.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("btv,btv->bt", logits, onehot)
    return jnp.mean(lse - gold)


CE_IMPLS = {"gather": cross_entropy, "onehot": cross_entropy_onehot}


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 0.01,
                 remat: bool = True, unroll: bool = False,
                 ce_impl: str = "gather") -> Callable:
    ce_fn = CE_IMPLS[ce_impl]

    def loss_fn(params, tokens, labels, extras: Optional[Dict] = None):
        extras = extras or {}
        logits, aux, _ = T.forward(params, cfg, tokens, remat=remat,
                                   unroll=unroll, **extras)
        ce = ce_fn(logits, labels)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "moe_aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    microbatches: int = 1, remat: bool = True,
                    clip_norm: float = 1.0, aux_weight: float = 0.01,
                    extras_fn: Optional[Callable[[jnp.ndarray], Dict]] = None,
                    unroll: bool = False, ce_impl: str = "gather",
                    ) -> Callable[[TrainState, Tuple], Tuple[TrainState, Dict]]:
    """Build ``train_step(state, (tokens, labels)) -> (state, metrics)``.

    ``microbatches>1`` accumulates gradients over a ``lax.scan`` across
    batch slices (activation memory / num_microbatches).  ``extras_fn``
    produces stub frontend inputs (VLM prefix embeds / audio encoder
    frames) from the token batch.
    """
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, remat=remat,
                           unroll=unroll, ce_impl=ce_impl)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def micro(params, tokens, labels):
        extras = extras_fn(tokens) if extras_fn else {}
        (loss, met), grads = grad_fn(params, tokens, labels, extras)
        return loss, met, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        tokens, labels = batch
        if microbatches == 1:
            loss, met, grads = micro(state.params, tokens, labels)
        else:
            B = tokens.shape[0]
            assert B % microbatches == 0
            mb = B // microbatches
            tk = tokens.reshape(microbatches, mb, *tokens.shape[1:])
            lb = labels.reshape(microbatches, mb, *labels.shape[1:])

            def body(acc, xs):
                t, l = xs
                loss, met, grads = micro(state.params, t, l)
                acc_loss, acc_met, acc_g = acc
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                acc_met = jax.tree.map(jnp.add, acc_met, met)
                return (acc_loss + loss, acc_met, acc_g), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            zero_m = {"ce": jnp.zeros(()), "moe_aux": jnp.zeros(())}
            acc0 = (jnp.zeros(()), zero_m, zero_g)
            if unroll:
                # python loop: exact HLO cost accounting (scan bodies are
                # counted once by XLA's cost analysis — the dry-run
                # unrolls its measurement compiles)
                acc = acc0
                for i in range(microbatches):
                    acc, _ = body(acc, (tk[i], lb[i]))
                loss, met, grads = acc
            else:
                (loss, met, grads), _ = jax.lax.scan(body, acc0, (tk, lb))
            inv = 1.0 / microbatches
            loss = loss * inv
            met = jax.tree.map(lambda x: x * inv, met)
            grads = jax.tree.map(lambda g: g * inv, grads)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt = optimizer.update(grads, state.opt_state,
                                            state.params)
        new_params = jax.tree.map(jnp.add, state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **met}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


# --------------------------------------------------------------------- #
# inference steps (what the dry-run lowers for prefill/decode shapes)
# --------------------------------------------------------------------- #
def make_prefill_step(cfg: ModelConfig,
                      extras_fn: Optional[Callable] = None,
                      unroll: bool = False) -> Callable:
    """Full-prompt forward returning last-position logits (B, 1, V)."""
    def prefill_step(params, tokens):
        extras = extras_fn(tokens) if extras_fn else {}
        logits, _, _ = T.forward(params, cfg, tokens, last_only=True,
                                 unroll=unroll, **extras)
        return logits
    return prefill_step


def make_serve_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    """One-token decode against a dense cache of seq_len tokens."""
    def serve_step(params, tokens, cache, cache_len):
        logits, new_cache = T.decode_step(params, cfg, tokens, cache,
                                          cache_len, unroll=unroll)
        return logits, new_cache, cache_len + 1
    return serve_step
