"""Version shims for jax API renames used by the Pallas kernels."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; take
# whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:   # pragma: no cover - depends on jax build
    raise ImportError(
        "unsupported jax version: pallas tpu exposes neither "
        "CompilerParams nor TPUCompilerParams")
