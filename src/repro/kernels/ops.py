"""Jit'd wrappers around the CoDec kernels + the XLA fallback impl.

``codec_attention`` is the public op: stacked decode queries + paged KV
pool + a compiled ``DecodePlan`` -> attention outputs, with three
interchangeable implementations:

* ``pallas``  — the PAC kernel (interpret=True on CPU, compiled on TPU);
* ``xla``     — the same task/plan semantics expressed as dense jnp ops
                (vectorised over tasks); this is what the distributed
                serve_step lowers, so the multi-pod dry-run exercises the
                paper's plan structure without Pallas;
* ``ref``     — the python-loop oracle from ``ref.py``.

All implementations share the flattened segment-LSE reduction
(``combine_partials``) — the TPU-native tree reduction.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import pac as pac_mod
from . import ref as ref_mod

MASK_VALUE = ref_mod.MASK_VALUE


class PlanArrays(NamedTuple):
    """Device-ready DecodePlan arrays (all jnp, static shapes)."""
    step_task: jnp.ndarray
    step_page: jnp.ndarray
    step_valid: jnp.ndarray
    step_first: jnp.ndarray
    step_last: jnp.ndarray
    step_pos: jnp.ndarray
    step_kvlen: jnp.ndarray
    task_qnum: jnp.ndarray
    task_npages: jnp.ndarray
    task_kvlen: jnp.ndarray
    task_pos: jnp.ndarray
    task_pages: jnp.ndarray
    q_gather: jnp.ndarray
    q_pos: jnp.ndarray
    seg_ids: jnp.ndarray


def plan_arrays(plan) -> PlanArrays:
    return PlanArrays(*(jnp.asarray(getattr(plan, f)) for f in PlanArrays._fields))


def advance_plan_arrays(pa: PlanArrays, delta) -> PlanArrays:
    """Advance all query positions by ``delta`` steps, device-side.

    Between plan rebuilds every live query moves one position per decode
    step; the fused step passes the epoch-relative step counter instead
    of re-uploading plan arrays.  Dead q-slots advance too — harmless,
    they are masked out by ``task_qnum`` in every implementation.
    """
    return pa._replace(q_pos=pa.q_pos + jnp.asarray(delta, jnp.int32))


@functools.partial(jax.jit, static_argnames=("num_queries",))
def combine_partials(o_parts: jnp.ndarray, m_parts: jnp.ndarray,
                     l_parts: jnp.ndarray, seg_ids: jnp.ndarray,
                     num_queries: int) -> jnp.ndarray:
    """Flattened parallel tree reduction (POR collapsed to segment LSE)."""
    P = o_parts.shape[0] * o_parts.shape[1]
    h, d = o_parts.shape[2], o_parts.shape[3]
    return ref_mod.combine_partials_ref(
        o_parts.reshape(P, h, d), m_parts.reshape(P, h),
        l_parts.reshape(P, h), seg_ids, num_queries)


@functools.partial(jax.jit, static_argnames=("num_queries",))
def combine_partials_stats(o_parts, m_parts, l_parts, seg_ids,
                           num_queries: int):
    """Like combine_partials but returns mergeable per-query (o, m, l)."""
    P = o_parts.shape[0] * o_parts.shape[1]
    h, d = o_parts.shape[2], o_parts.shape[3]
    return ref_mod.combine_partials_stats_ref(
        o_parts.reshape(P, h, d), m_parts.reshape(P, h),
        l_parts.reshape(P, h), seg_ids, num_queries)


@functools.partial(jax.jit, static_argnames=("window",))
def single_page_attention(q: jnp.ndarray,        # (B, h_q, d)
                          k_pages: jnp.ndarray,  # (B, page, n_kv, d)
                          v_pages: jnp.ndarray,
                          pos_base: jnp.ndarray,  # (B,) abs pos of page[0]
                          q_pos: jnp.ndarray,     # (B,)
                          window: int = 0):
    """Per-request attention over one (tail) page -> partial (o, m, l).

    The engine's growing-tail fast path: the frozen CoDec plan covers all
    full pages; this covers each request's last partial page and the
    result is POR-merged with the frozen partials.
    """
    def one(qb, kb, vb, pb, qp):
        return ref_mod.pac_ref(qb[None], kb, vb,
                               kv_len=None, pos_base=pb,
                               q_pos=qp[None], window=window)

    o, m, l = jax.vmap(one)(q, k_pages, v_pages,
                            pos_base.astype(jnp.int32),
                            q_pos.astype(jnp.int32))
    return o[:, 0], m[:, 0], l[:, 0]


def gather_queries(q: jnp.ndarray, q_gather: jnp.ndarray) -> jnp.ndarray:
    """(B, h, d) -> task-major (T+1, max_q, h, d)."""
    return q[q_gather]


# --------------------------------------------------------------------- #
# XLA implementation of PAC over the task-major plan arrays
# --------------------------------------------------------------------- #
def pac_xla(q_tasks: jnp.ndarray,     # (T+1, max_q, h_q, d)
            qpos_tasks: jnp.ndarray,  # (T+1, max_q)
            k_pool: jnp.ndarray,      # (P, page, n_kv, d)
            v_pool: jnp.ndarray,
            task_pages: jnp.ndarray,  # (T+1, max_pages)
            task_kvlen: jnp.ndarray,  # (T+1,)
            task_pos: jnp.ndarray,    # (T+1,)
            window: int = 0,
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    Tp1, max_q, h_q, d = q_tasks.shape
    _, page, n_kv, _ = k_pool.shape
    max_pages = task_pages.shape[1]
    n = max_pages * page
    group = h_q // n_kv
    scale = 1.0 / np.sqrt(d)

    k_t = k_pool[task_pages].reshape(Tp1, n, n_kv, d)
    v_t = v_pool[task_pages].reshape(Tp1, n, n_kv, d)

    qf = (q_tasks.astype(jnp.float32)
          .reshape(Tp1, max_q, n_kv, group, d)
          .transpose(0, 2, 1, 3, 4)
          .reshape(Tp1, n_kv, max_q * group, d))
    kf = k_t.astype(jnp.float32).transpose(0, 2, 1, 3)   # (T, n_kv, n, d)
    vf = v_t.astype(jnp.float32).transpose(0, 2, 1, 3)

    s = jnp.einsum("thrd,thnd->thrn", qf, kf) * scale

    off = jnp.arange(n, dtype=jnp.int32)
    pos = task_pos[:, None].astype(jnp.int32) + off[None, :]   # (T, n)
    valid = off[None, :] < task_kvlen[:, None]
    qp = qpos_tasks.astype(jnp.int32)                          # (T, max_q)
    mask = valid[:, None, :] & (pos[:, None, :] <= qp[:, :, None])
    if window > 0:
        mask = mask & (pos[:, None, :] > qp[:, :, None] - window)
    # (T, max_q, n) -> (T, n_kv, max_q*group, n)
    mask_r = jnp.broadcast_to(mask[:, :, None, :], (Tp1, max_q, group, n))
    mask_r = mask_r.reshape(Tp1, 1, max_q * group, n)
    mask_r = jnp.broadcast_to(mask_r, s.shape)

    s = jnp.where(mask_r, s, MASK_VALUE)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * mask_r
    l = jnp.sum(p, axis=-1)
    u = jnp.einsum("thrn,thnd->thrd", p, vf)
    o = u / jnp.maximum(l, 1e-30)[..., None]

    def unfold(x):
        tail = x.shape[3:]
        return (x.reshape(Tp1, n_kv, max_q, group, *tail)
                 .transpose(0, 2, 1, 3, *(4 + i for i in range(len(tail))))
                 .reshape(Tp1, max_q, h_q, *tail))

    return unfold(o), unfold(m), unfold(l)


# --------------------------------------------------------------------- #
# public op
# --------------------------------------------------------------------- #
@functools.partial(
    jax.jit,
    static_argnames=("num_queries", "window", "impl", "interpret"))
def codec_partials_arrays(q: jnp.ndarray, k_pool: jnp.ndarray,
                          v_pool: jnp.ndarray, pa: PlanArrays,
                          num_queries: int, *, window: int = 0,
                          impl: str = "pallas",
                          interpret: bool = True):
    """Plan-covered attention -> per-query mergeable (o, m, l) stats."""
    q_tasks = gather_queries(q, pa.q_gather)
    if impl == "pallas":
        o, m, l = pac_mod.pac(
            q_tasks, pa.q_pos, k_pool, v_pool,
            pa.step_task, pa.step_page, pa.step_valid, pa.step_first,
            pa.step_last, pa.step_pos, pa.step_kvlen,
            window=window, interpret=interpret,
            num_lanes=pa.step_task.shape[0],
            max_steps=pa.step_task.shape[1])
    elif impl == "xla":
        o, m, l = pac_xla(q_tasks, pa.q_pos, k_pool, v_pool,
                          pa.task_pages, pa.task_kvlen, pa.task_pos,
                          window=window)
    else:
        raise ValueError(impl)
    # zero-out padding slots so stale/trash flushes can't reach a segment
    slot = jnp.arange(pa.q_gather.shape[1], dtype=jnp.int32)
    live = slot[None, :] < pa.task_qnum[:, None]              # (T+1, max_q)
    m = jnp.where(live[..., None], m, MASK_VALUE)
    l = jnp.where(live[..., None], l, 0.0)
    o = jnp.where(live[..., None, None], o, 0.0)  # trash may hold NaNs
    return combine_partials_stats(o, m, l, pa.seg_ids, num_queries)


def codec_attention_arrays(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, pa: PlanArrays,
                           num_queries: int, *, window: int = 0,
                           impl: str = "pallas",
                           interpret: bool = True) -> jnp.ndarray:
    out, _, _ = codec_partials_arrays(q, k_pool, v_pool, pa, num_queries,
                                      window=window, impl=impl,
                                      interpret=interpret)
    return out.astype(q.dtype)


def codec_attention(q, k_pool, v_pool, plan, *, impl: str = "pallas",
                    window: int = 0, interpret: bool = True) -> jnp.ndarray:
    """Convenience entry taking a host DecodePlan object."""
    if impl == "ref":
        return ref_mod.codec_ref(q, k_pool, v_pool, plan).astype(q.dtype)
    return codec_attention_arrays(q, k_pool, v_pool, plan_arrays(plan),
                                  plan.num_queries, window=window,
                                  impl=impl, interpret=interpret)
