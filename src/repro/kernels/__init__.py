# Decode-attention kernels + the pluggable backend registry.
#
# ``registry`` is the public resolution point: string name -> backend
# (codec-pallas / codec-xla / flash / hydragen / ref).  ``pac``/``por``
# are the Pallas TPU kernels, ``ops`` the jit'd wrappers + XLA fallback,
# ``hydragen`` the batched shared-prefix backend, ``ref`` the oracles.
