"""Pure-jnp oracles for the CoDec kernels.

Everything here is deliberately simple and materialises full score
matrices; used only as the ground truth for kernel tests and the `ref`
attention impl.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MASK_VALUE = -1e30


def _fold_gqa(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(nq, h_q, d) -> (n_kv, nq*group, d); head h belongs to kv h//group."""
    nq, h_q, d = q.shape
    group = h_q // n_kv
    return (q.reshape(nq, n_kv, group, d)
             .transpose(1, 0, 2, 3)
             .reshape(n_kv, nq * group, d))


def _unfold_gqa(x: jnp.ndarray, nq: int) -> jnp.ndarray:
    """(n_kv, nq*group, ...) -> (nq, h_q, ...)."""
    n_kv, rows = x.shape[:2]
    group = rows // nq
    tail = x.shape[2:]
    return (x.reshape(n_kv, nq, group, *tail)
             .transpose(1, 0, 2, *(3 + i for i in range(len(tail))))
             .reshape(nq, n_kv * group, *tail))


def pac_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            kv_len: Optional[int] = None,
            pos_base: int = 0,
            q_pos: Optional[jnp.ndarray] = None,
            window: int = 0,
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial attention computation (paper Alg. 2) + flash statistics.

    q: (nq, h_q, d); k, v: (n, n_kv, d).  Returns (o, m, l) with
    o: (nq, h_q, d) normalised *within this node*, m: (nq, h_q) running
    max (log-space frame), l: (nq, h_q) softmax denominator at frame m.
    ``kv_len`` masks padding rows of k/v; ``pos_base``/``q_pos``/``window``
    implement the visibility mask of §4.1.
    """
    nq, h_q, d = q.shape
    n, n_kv, _ = k.shape
    scale = 1.0 / np.sqrt(d)
    qf = _fold_gqa(q.astype(jnp.float32), n_kv)              # (n_kv, R, d)
    kf = k.astype(jnp.float32).transpose(1, 0, 2)            # (n_kv, n, d)
    vf = v.astype(jnp.float32).transpose(1, 0, 2)
    s = jnp.einsum("hrd,hnd->hrn", qf, kf) * scale           # (n_kv, R, n)

    pos = pos_base + jnp.arange(n)
    valid = jnp.ones(n, bool) if kv_len is None else pos < pos_base + kv_len
    mask = jnp.broadcast_to(valid[None, :], (nq, n))
    if q_pos is not None:
        qp = q_pos.astype(jnp.int32)[:, None]
        mask = mask & (pos[None, :] <= qp)                   # causality
        if window and window > 0:
            mask = mask & (pos[None, :] > qp - window)
    group = h_q // n_kv
    mask_r = jnp.repeat(mask, group, axis=0).reshape(nq, group, n)
    mask_r = jnp.broadcast_to(mask_r[None], (n_kv, nq, group, n))
    mask_r = mask_r.reshape(n_kv, nq * group, n)

    s = jnp.where(mask_r, s, MASK_VALUE)
    m = jnp.max(s, axis=-1)                                  # (n_kv, R)
    p = jnp.exp(s - m[..., None]) * mask_r
    l = jnp.sum(p, axis=-1)
    u = jnp.einsum("hrn,hnd->hrd", p, vf)
    o = u / jnp.maximum(l, 1e-30)[..., None]
    return (_unfold_gqa(o, nq), _unfold_gqa(m, nq), _unfold_gqa(l, nq))


def por_ref(o1, m1, l1, o2, m2, l2):
    """Partial output reduction (paper Alg. 3): LSE merge of two partials."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * l1
    a2 = jnp.exp(m2 - m) * l2
    l = a1 + a2
    o = (o1 * a1[..., None] + o2 * a2[..., None]) / jnp.maximum(l, 1e-30)[..., None]
    return o, m, l


def combine_partials_stats_ref(o_parts, m_parts, l_parts, seg_ids,
                               num_queries):
    """Segment-LSE reduction returning per-query (o, m, l) partials.

    o_parts: (P, h, d); m/l: (P, h); seg_ids: (P,) in [0, num_queries]
    (num_queries = trash).  Returns ((B,h,d), (B,h), (B,h)) — itself a
    valid partial, so the result can be POR-merged with further partials
    (e.g. the engine's per-step tail page, or a cross-device shard).
    """
    num_seg = num_queries + 1
    m_max = jax.ops.segment_max(m_parts, seg_ids, num_segments=num_seg)
    m_max = jnp.maximum(m_max, MASK_VALUE)  # empty segments -> -inf guard
    alpha = jnp.exp(m_parts - m_max[seg_ids]) * l_parts
    denom = jax.ops.segment_sum(alpha, seg_ids, num_segments=num_seg)
    numer = jax.ops.segment_sum(o_parts * alpha[..., None], seg_ids,
                                num_segments=num_seg)
    out = numer / jnp.maximum(denom, 1e-30)[..., None]
    return (out[:num_queries], m_max[:num_queries], denom[:num_queries])


def combine_partials_ref(o_parts, m_parts, l_parts, seg_ids, num_queries):
    """Flattened segment-LSE reduction (our TPU-native tree reduction)."""
    o, _, _ = combine_partials_stats_ref(o_parts, m_parts, l_parts, seg_ids,
                                         num_queries)
    return o


def decode_attention_ref(q, k, v, kv_lens, window: int = 0):
    """Dense-batch decode attention oracle (the FlashDecoding semantics).

    q: (B, h_q, d); k, v: (B, L, n_kv, d); kv_lens: (B,).
    Query position of request b is kv_lens[b] - 1... the query attends to
    all cached positions [0, kv_lens[b]) (its own KV is already appended).
    """
    B, h_q, d = q.shape

    def one(qb, kb, vb, ln):
        o, _, _ = pac_ref(qb[None].reshape(1, h_q, d) if qb.ndim == 2 else qb,
                          kb, vb, kv_len=ln,
                          q_pos=jnp.full((1,), ln - 1, jnp.int32),
                          window=window)
        return o[0]

    return jax.vmap(lambda qb, kb, vb, ln: one(qb[None], kb, vb, ln))(
        q, k, v, kv_lens.astype(jnp.int32))


def codec_ref_stats(q, k_pool, v_pool, plan, window: int = 0):
    """Shared-prefix decode attention oracle driven by a DecodePlan.

    q: (B, h_q, d); pools: (P, page, n_kv, d).  Loops tasks in Python —
    slow, exact.  Returns per-query mergeable (o, m, l).
    """
    ps = plan.page_size
    parts_o, parts_m, parts_l, segs = [], [], [], []
    for t in range(plan.num_tasks):
        npages = int(plan.task_npages[t])
        kvlen = int(plan.task_kvlen[t])
        nq = int(plan.task_qnum[t])
        if nq == 0 or kvlen == 0:
            continue
        pages = np.asarray(plan.task_pages[t, :npages])
        k = k_pool[pages].reshape(npages * ps, *k_pool.shape[2:])
        v = v_pool[pages].reshape(npages * ps, *v_pool.shape[2:])
        rows = np.asarray(plan.q_gather[t, :nq])
        qt = q[rows]
        qp = jnp.asarray(plan.q_pos[t, :nq])
        o, m, l = pac_ref(qt, k, v, kv_len=kvlen,
                          pos_base=int(plan.task_pos[t]), q_pos=qp,
                          window=window)
        parts_o.append(o); parts_m.append(m); parts_l.append(l)
        segs.append(rows)
    o_parts = jnp.concatenate(parts_o, 0)
    m_parts = jnp.concatenate(parts_m, 0)
    l_parts = jnp.concatenate(parts_l, 0)
    seg_ids = jnp.concatenate([jnp.asarray(s) for s in segs], 0)
    return combine_partials_stats_ref(o_parts, m_parts, l_parts, seg_ids,
                                      plan.num_queries)


def codec_ref(q, k_pool, v_pool, plan) -> jnp.ndarray:
    """Full-output convenience wrapper around ``codec_ref_stats``."""
    o, _, _ = codec_ref_stats(q, k_pool, v_pool, plan)
    return o
