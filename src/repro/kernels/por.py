"""CoDec POR (partial output reduction) Pallas kernel (paper Alg. 3).

Binary log-sum-exp merge of two partial-output sets belonging to the same
queries.  The serving path normally uses the flattened segment reduction in
``ops.combine_partials`` (one pass, maximal parallelism — our TPU-native
form of the paper's parallel tree reduction), but the pairwise kernel is
kept (a) as the literal paper primitive, property-tested for the
associativity/commutativity the tree reduction relies on, and (b) for the
cross-device sequence-parallel combine where exactly two partials meet.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import _CompilerParams


def _por_kernel(o1_ref, m1_ref, l1_ref, o2_ref, m2_ref, l2_ref,
                o_ref, m_ref, l_ref):
    m1 = m1_ref[...]
    m2 = m2_ref[...]
    l1 = l1_ref[...]
    l2 = l2_ref[...]
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * l1
    a2 = jnp.exp(m2 - m) * l2
    l = a1 + a2
    l_safe = jnp.maximum(l, 1e-30)
    o = (o1_ref[...] * a1[..., None] + o2_ref[...] * a2[..., None]) / l_safe[..., None]
    o_ref[...] = o
    m_ref[...] = m
    l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def por(o1: jnp.ndarray, m1: jnp.ndarray, l1: jnp.ndarray,
        o2: jnp.ndarray, m2: jnp.ndarray, l2: jnp.ndarray,
        *, block_rows: int = 128, interpret: bool = True,
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge partials. o*: (N, h, d) f32; m*/l*: (N, h) f32."""
    n, h, d = o1.shape
    block_rows = min(block_rows, n)
    grid = (-(-n // block_rows),)

    o_spec = pl.BlockSpec((block_rows, h, d), lambda i: (i, 0, 0))
    ml_spec = pl.BlockSpec((block_rows, h), lambda i: (i, 0))

    return pl.pallas_call(
        _por_kernel,
        grid=grid,
        in_specs=[o_spec, ml_spec, ml_spec, o_spec, ml_spec, ml_spec],
        out_specs=[o_spec, ml_spec, ml_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, h, d), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(o1, m1, l1, o2, m2, l2)
