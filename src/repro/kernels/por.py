"""CoDec POR (partial output reduction) Pallas kernel (paper Alg. 3).

Binary log-sum-exp merge of two partial-output sets belonging to the same
queries.  The serving path normally uses the flattened segment reduction in
``ops.combine_partials`` (one pass, maximal parallelism — our TPU-native
form of the paper's parallel tree reduction), but the pairwise kernel is
kept (a) as the literal paper primitive, property-tested for the
associativity/commutativity the tree reduction relies on, and (b) for the
cross-device sequence-parallel combine where exactly two partials meet.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import _CompilerParams
from . import ref as ref_mod


def _por_kernel(o1_ref, m1_ref, l1_ref, o2_ref, m2_ref, l2_ref,
                o_ref, m_ref, l_ref):
    m1 = m1_ref[...]
    m2 = m2_ref[...]
    l1 = l1_ref[...]
    l2 = l2_ref[...]
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * l1
    a2 = jnp.exp(m2 - m) * l2
    l = a1 + a2
    l_safe = jnp.maximum(l, 1e-30)
    o = (o1_ref[...] * a1[..., None] + o2_ref[...] * a2[..., None]) / l_safe[..., None]
    o_ref[...] = o
    m_ref[...] = m
    l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def por(o1: jnp.ndarray, m1: jnp.ndarray, l1: jnp.ndarray,
        o2: jnp.ndarray, m2: jnp.ndarray, l2: jnp.ndarray,
        *, block_rows: int = 128, interpret: bool = True,
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge partials. o*: (N, h, d) f32; m*/l*: (N, h) f32."""
    n, h, d = o1.shape
    block_rows = min(block_rows, n)
    grid = (-(-n // block_rows),)

    o_spec = pl.BlockSpec((block_rows, h, d), lambda i: (i, 0, 0))
    ml_spec = pl.BlockSpec((block_rows, h), lambda i: (i, 0))

    return pl.pallas_call(
        _por_kernel,
        grid=grid,
        in_specs=[o_spec, ml_spec, ml_spec, o_spec, ml_spec, ml_spec],
        out_specs=[o_spec, ml_spec, ml_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, h, d), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(o1, m1, l1, o2, m2, l2)


# --------------------------------------------------------------------- #
# cross-device sequence-parallel merge (SPMD decode, under shard_map)
# --------------------------------------------------------------------- #
def por_allmerge(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray,
                 axis_name: str, axis_size: int,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All-reduce the per-query partials over a mesh axis using POR only.

    Recursive-doubling butterfly: ``log2(axis_size)`` ``ppermute``
    rounds, each followed by one pairwise POR merge — no ``psum`` and no
    ``all_gather`` (an LSE merge is not a sum, so ``psum`` cannot
    express it, and gathering all partials would move ``axis_size``
    copies instead of ``log2``).  After the last round every device
    holds the full merge identically in max space (``m`` — pure
    ``maximum`` commutes bitwise) and to one FMA slot asymmetry in
    ``o``/``l``: XLA fuses ``o1*a1 + o2*a2`` as
    ``fma(o_local, a_local, o_recv*a_recv)``, and the local/received
    operand roles swap between XOR partners, so the two sides round
    once differently (±1 ulp).  Sampling consumes device 0's logits
    (replicated out-spec), so token streams stay deterministic.

    Requires ``axis_size`` to be a power of two (mesh data axes are).
    Partials over disjoint KV slices are exactly what this merges — each
    data-shard's plan covers only the KV pages resident on that shard.
    """
    if axis_size <= 1:
        return o, m, l
    if axis_size & (axis_size - 1):
        raise ValueError(f"por_allmerge needs a power-of-two axis, "
                         f"got {axis_size}")
    shift = 1
    while shift < axis_size:
        perm = [(i, i ^ shift) for i in range(axis_size)]
        o2 = jax.lax.ppermute(o, axis_name, perm)
        m2 = jax.lax.ppermute(m, axis_name, perm)
        l2 = jax.lax.ppermute(l, axis_name, perm)
        o, m, l = ref_mod.por_ref(o, m, l, o2, m2, l2)
        shift *= 2
    return o, m, l


def _pack(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([o, m[..., None], l[..., None]], axis=-1)


def _unpack(p: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    return p[..., :-2], p[..., -2], p[..., -1]


def por_subgroup_merge(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray,
                       axis_name: str, axis_size: int,
                       contrib: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sparse POR merge over the minimal subgroup of contributing shards.

    Same result contract as :func:`por_allmerge` — after the call every
    device on the axis holds the merged partials (bitwise in max space,
    to FMA slot asymmetry in ``o``/``l``, and **bitwise verbatim** when
    a single shard contributes) — but with two cost improvements for
    the sparse sharded-decode case:

    * **one packed transfer per round**: ``(o, m, l)`` ride in a single
      ``(rows, h, d + 2)`` f32 buffer, so each butterfly round issues
      ONE ``ppermute`` (and pays one launch) instead of three;
    * **subgroup rounds**: ``contrib`` is a traced ``(axis_size,)`` bool
      vector marking the shards that hold non-identity partials for the
      packed rows (from the plan's ownership mask).  With contributors
      confined to an aligned block of ``2^k`` devices, only the first
      ``k`` rounds are *merge* rounds (ppermute + pairwise POR inside
      the block); the remaining ``log2(axis_size) - k`` rounds degrade
      to *copy* rounds — the block's finished result is forwarded
      verbatim (``where`` select, no float math), doubling the holder
      set each round until the axis is covered.  Copy rounds move the
      same bytes but skip the POR FLOPs and, crucially, are bitwise
      round-trips, so devices with no contribution introduce zero float
      perturbation.

    The round structure is selected with traced predicates (anchor =
    first contributor, ``xall`` = OR-fold of ``id XOR anchor`` over
    contributors; round ``s`` merges iff ``xall >= s``), so ONE compiled
    program serves every ownership pattern — the mask does not enter
    the jit signature.  Devices outside the contributor block feed
    identity partials (``m = MASK, l = 0``) into nothing: their rows
    are overwritten by the copy cascade.

    Requires ``axis_size`` to be a power of two (mesh data axes are).
    """
    if axis_size <= 1:
        return o, m, l
    if axis_size & (axis_size - 1):
        raise ValueError(f"por_subgroup_merge needs a power-of-two axis, "
                         f"got {axis_size}")
    c = contrib.astype(jnp.int32)
    ids = jnp.arange(axis_size, dtype=jnp.int32)
    anchor = jnp.argmax(c).astype(jnp.int32)   # first contributor (0 if none)
    xall = jnp.max(jnp.where(c > 0, ids ^ anchor, 0))
    me = jax.lax.axis_index(axis_name)
    packed = _pack(o, m, l)
    shift = 1
    while shift < axis_size:
        perm = [(i, i ^ shift) for i in range(axis_size)]
        recv = jax.lax.ppermute(packed, axis_name, perm)
        og, mg, lg = ref_mod.por_ref(*_unpack(packed), *_unpack(recv))
        merged = _pack(og, mg, lg)
        # copy round: anchor's aligned shift-block already holds the
        # finished merge; its XOR partners receive it verbatim
        have = (me // shift) == (anchor // shift)
        copied = jnp.where(have, packed, recv)
        packed = jnp.where(xall >= shift, merged, copied)
        shift *= 2
    return _unpack(packed)
