"""CoDec POR (partial output reduction) Pallas kernel (paper Alg. 3).

Binary log-sum-exp merge of two partial-output sets belonging to the same
queries.  The serving path normally uses the flattened segment reduction in
``ops.combine_partials`` (one pass, maximal parallelism — our TPU-native
form of the paper's parallel tree reduction), but the pairwise kernel is
kept (a) as the literal paper primitive, property-tested for the
associativity/commutativity the tree reduction relies on, and (b) for the
cross-device sequence-parallel combine where exactly two partials meet.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import _CompilerParams
from . import ref as ref_mod


def _por_kernel(o1_ref, m1_ref, l1_ref, o2_ref, m2_ref, l2_ref,
                o_ref, m_ref, l_ref):
    m1 = m1_ref[...]
    m2 = m2_ref[...]
    l1 = l1_ref[...]
    l2 = l2_ref[...]
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * l1
    a2 = jnp.exp(m2 - m) * l2
    l = a1 + a2
    l_safe = jnp.maximum(l, 1e-30)
    o = (o1_ref[...] * a1[..., None] + o2_ref[...] * a2[..., None]) / l_safe[..., None]
    o_ref[...] = o
    m_ref[...] = m
    l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def por(o1: jnp.ndarray, m1: jnp.ndarray, l1: jnp.ndarray,
        o2: jnp.ndarray, m2: jnp.ndarray, l2: jnp.ndarray,
        *, block_rows: int = 128, interpret: bool = True,
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge partials. o*: (N, h, d) f32; m*/l*: (N, h) f32."""
    n, h, d = o1.shape
    block_rows = min(block_rows, n)
    grid = (-(-n // block_rows),)

    o_spec = pl.BlockSpec((block_rows, h, d), lambda i: (i, 0, 0))
    ml_spec = pl.BlockSpec((block_rows, h), lambda i: (i, 0))

    return pl.pallas_call(
        _por_kernel,
        grid=grid,
        in_specs=[o_spec, ml_spec, ml_spec, o_spec, ml_spec, ml_spec],
        out_specs=[o_spec, ml_spec, ml_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, h, d), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
            jax.ShapeDtypeStruct((n, h), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(o1, m1, l1, o2, m2, l2)


# --------------------------------------------------------------------- #
# cross-device sequence-parallel merge (SPMD decode, under shard_map)
# --------------------------------------------------------------------- #
def por_allmerge(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray,
                 axis_name: str, axis_size: int,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All-reduce the per-query partials over a mesh axis using POR only.

    Recursive-doubling butterfly: ``log2(axis_size)`` ``ppermute``
    rounds, each followed by one pairwise POR merge — no ``psum`` and no
    ``all_gather`` (an LSE merge is not a sum, so ``psum`` cannot
    express it, and gathering all partials would move ``axis_size``
    copies instead of ``log2``).  After the last round every device
    holds the full merge **bitwise identically**: the pairwise POR is
    commutative at float level (``max`` and two-term adds commute
    bitwise), so XOR partners compute equal results each round.

    Requires ``axis_size`` to be a power of two (mesh data axes are).
    Partials over disjoint KV slices are exactly what this merges — each
    data-shard's plan covers only the KV pages resident on that shard.
    """
    if axis_size <= 1:
        return o, m, l
    if axis_size & (axis_size - 1):
        raise ValueError(f"por_allmerge needs a power-of-two axis, "
                         f"got {axis_size}")
    shift = 1
    while shift < axis_size:
        perm = [(i, i ^ shift) for i in range(axis_size)]
        o2 = jax.lax.ppermute(o, axis_name, perm)
        m2 = jax.lax.ppermute(m, axis_name, perm)
        l2 = jax.lax.ppermute(l, axis_name, perm)
        o, m, l = ref_mod.por_ref(o, m, l, o2, m2, l2)
        shift *= 2
    return o, m, l
