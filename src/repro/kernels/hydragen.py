"""Hydragen-style batched shared-prefix decode attention.

A distinct point in the shared-prefix design space (Juravsky et al.,
"Hydragen"; Ye et al., "ChunkAttention"): instead of CoDec's page-level
task scheduling, decompose decode attention into

1. **prefix phase** — for every *shared* forest node, attention of all
   sharing queries against the node's KV as ONE batched dense matmul.
   Because every prefix token precedes every live query position, no
   causal comparison is needed inside the matmul (only page-remainder
   validity, plus the sliding-window bound when ``window > 0``) — the
   score computation is a pure GEMM, which is the source of Hydragen's
   throughput on matmul-heavy accelerators.
2. **suffix phase** — per-request attention over each request's private
   (single-query) KV slices, batched across requests.
3. **merge** — both phases emit flash partials ``(o, m, l)`` that the
   standard segment log-sum-exp reduction (``ref.combine_partials``)
   folds into exact full-softmax outputs.

No new planner is needed: ``prepare`` consumes the existing
``DecodePlan`` task-major arrays (``q_gather`` / ``task_pages`` /
``q_pos``) and splits tasks by sharing degree on the host — shared
tasks (``task_qnum > 1``) form the prefix batch, single-query tasks the
suffix batch.  Window pruning done by the planner therefore carries
over unchanged.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as ref_mod

MASK_VALUE = ref_mod.MASK_VALUE


class HydragenArrays(NamedTuple):
    """Device arrays for the two phases (static shapes per plan)."""

    # shared-prefix groups: (S, ...) — tasks with > 1 sharing query
    px_pages: jnp.ndarray    # (S, max_pages) global page ids
    px_kvlen: jnp.ndarray    # (S,) valid tokens in the slice
    px_pos: jnp.ndarray      # (S,) absolute position of first token
    px_qnum: jnp.ndarray     # (S,) live queries of the group
    px_gather: jnp.ndarray   # (S, max_q) query rows (pad 0)
    px_qpos: jnp.ndarray     # (S, max_q) absolute query positions
    px_seg: jnp.ndarray      # (S * max_q,) segment ids (trash = B)

    # per-request suffixes: (U, ...) — single-query tasks
    sf_pages: jnp.ndarray    # (U, max_pages)
    sf_kvlen: jnp.ndarray    # (U,)
    sf_pos: jnp.ndarray      # (U,)
    sf_gather: jnp.ndarray   # (U,) the one query row
    sf_qpos: jnp.ndarray     # (U,)
    sf_seg: jnp.ndarray      # (U,)


def _bucket_rows(n: int) -> int:
    """Bucketed group count: smallest power of two >= n (0 stays 0).

    Both phase batches are padded to bucketed row counts so the jitted
    phases (and the fused decode step wrapping them) keep stable shapes
    across plan rebuilds; padded rows are dead (``qnum 0`` / ``kvlen 0``,
    segment = trash) and fully masked.  An empty batch stays empty —
    ``hydragen_partials_arrays`` skips the phase at trace time.
    """
    return 0 if n <= 0 else 1 << (n - 1).bit_length()


def prepare(plan) -> HydragenArrays:
    """Split a DecodePlan's tasks into prefix/suffix batches (host side)."""
    T = plan.num_tasks
    max_q = plan.max_q
    trash = plan.num_queries
    qnum = np.asarray(plan.task_qnum[:T])
    seg = np.asarray(plan.seg_ids[:(T + 1) * max_q]).reshape(-1, max_q)[:T]
    shared = np.nonzero(qnum > 1)[0]
    single = np.nonzero(qnum == 1)[0]
    S, U = _bucket_rows(len(shared)), _bucket_rows(len(single))

    def dev(a, rows, fill=0):
        a = np.ascontiguousarray(a)
        if a.shape[0] < rows:
            pad = np.full((rows - a.shape[0],) + a.shape[1:], fill, a.dtype)
            a = np.concatenate([a, pad], 0)
        return jnp.asarray(a)

    return HydragenArrays(
        px_pages=dev(plan.task_pages[shared], S),
        px_kvlen=dev(plan.task_kvlen[shared], S),
        px_pos=dev(plan.task_pos[shared], S),
        px_qnum=dev(qnum[shared], S),
        px_gather=dev(plan.q_gather[shared], S),
        px_qpos=dev(plan.q_pos[shared], S),
        px_seg=dev(seg[shared].reshape(-1), S * max_q, fill=trash),
        sf_pages=dev(plan.task_pages[single], U),
        sf_kvlen=dev(plan.task_kvlen[single], U),
        sf_pos=dev(plan.task_pos[single], U),
        sf_gather=dev(plan.q_gather[single, 0], U),
        sf_qpos=dev(plan.q_pos[single, 0], U),
        sf_seg=dev(seg[single, 0], U, fill=trash),
    )


def advance(ha: HydragenArrays, delta) -> HydragenArrays:
    """Advance all query positions by ``delta`` decode steps, device-side
    (dead slots advance too — they are masked by ``px_qnum`` / ``kvlen``)."""
    d = jnp.asarray(delta, jnp.int32)
    return ha._replace(px_qpos=ha.px_qpos + d, sf_qpos=ha.sf_qpos + d)


def _gather_kv(pool: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """(P, page, n_kv, d)[(G, max_pages)] -> (G, n, n_kv, d)."""
    G, max_pages = pages.shape
    page = pool.shape[1]
    return pool[pages].reshape(G, max_pages * page, *pool.shape[2:])


def _prefix_phase(q, k_pool, v_pool, ha: HydragenArrays, window: int):
    """Batched dense matmul per shared node — no causal comparison.

    Returns flattened partials: o (S*max_q, h, d), m/l (S*max_q, h).
    """
    S, max_q = ha.px_gather.shape
    _, _, n_kv, d = k_pool.shape
    h_q = q.shape[1]
    group = h_q // n_kv
    scale = 1.0 / np.sqrt(d)

    k_t = _gather_kv(k_pool, ha.px_pages)                 # (S, n, kv, d)
    v_t = _gather_kv(v_pool, ha.px_pages)
    n = k_t.shape[1]
    qg = q[ha.px_gather].astype(jnp.float32)              # (S, max_q, h, d)
    qf = (qg.reshape(S, max_q, n_kv, group, d)
          .transpose(0, 2, 1, 3, 4)
          .reshape(S, n_kv, max_q * group, d))
    kf = k_t.astype(jnp.float32).transpose(0, 2, 1, 3)    # (S, kv, n, d)
    vf = v_t.astype(jnp.float32).transpose(0, 2, 1, 3)

    # the Hydragen GEMM: every sharing query vs the whole node KV
    s = jnp.einsum("shrd,shnd->shrn", qf, kf) * scale

    off = jnp.arange(n, dtype=jnp.int32)
    valid = off[None, :] < ha.px_kvlen[:, None]           # (S, n) padding
    mask = jnp.broadcast_to(valid[:, None, :], (S, max_q, n))
    if window > 0:
        pos = ha.px_pos[:, None].astype(jnp.int32) + off[None, :]
        qp = ha.px_qpos.astype(jnp.int32)                 # (S, max_q)
        mask = mask & (pos[:, None, :] > qp[:, :, None] - window)
    mask_r = (jnp.broadcast_to(mask[:, :, None, :], (S, max_q, group, n))
              .reshape(S, 1, max_q * group, n))
    mask_r = jnp.broadcast_to(mask_r, s.shape)

    s = jnp.where(mask_r, s, MASK_VALUE)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * mask_r
    l = jnp.sum(p, axis=-1)
    u = jnp.einsum("shrn,shnd->shrd", p, vf)
    o = u / jnp.maximum(l, 1e-30)[..., None]

    def unfold(x):
        tail = x.shape[3:]
        return (x.reshape(S, n_kv, max_q, group, *tail)
                .transpose(0, 2, 1, 3, *(4 + i for i in range(len(tail))))
                .reshape(S * max_q, h_q, *tail))

    o, m, l = unfold(o), unfold(m), unfold(l)
    # dead query slots (slot >= qnum) must not pollute their gather row
    slot = jnp.arange(max_q, dtype=jnp.int32)
    live = (slot[None, :] < ha.px_qnum[:, None]).reshape(S * max_q)
    m = jnp.where(live[:, None], m, MASK_VALUE)
    l = jnp.where(live[:, None], l, 0.0)
    o = jnp.where(live[:, None, None], o, 0.0)
    return o, m, l


def _suffix_phase(q, k_pool, v_pool, ha: HydragenArrays, window: int):
    """Per-request attention over private KV slices, batched over tasks.

    Returns o (U, h, d), m/l (U, h).  The causal bound IS applied here:
    a suffix slice may contain the query's own newest token.
    """
    U = ha.sf_gather.shape[0]
    _, _, n_kv, d = k_pool.shape
    h_q = q.shape[1]
    group = h_q // n_kv
    scale = 1.0 / np.sqrt(d)

    k_t = _gather_kv(k_pool, ha.sf_pages)                 # (U, n, kv, d)
    v_t = _gather_kv(v_pool, ha.sf_pages)
    n = k_t.shape[1]
    qg = q[ha.sf_gather].astype(jnp.float32)              # (U, h, d)
    qf = qg.reshape(U, n_kv, group, d)    # head h = kv*group + g
    kf = k_t.astype(jnp.float32).transpose(0, 2, 1, 3)    # (U, kv, n, d)
    vf = v_t.astype(jnp.float32).transpose(0, 2, 1, 3)

    s = jnp.einsum("shgd,shnd->shgn", qf, kf) * scale     # (U, kv, g, n)

    off = jnp.arange(n, dtype=jnp.int32)
    pos = ha.sf_pos[:, None].astype(jnp.int32) + off[None, :]   # (U, n)
    qp = ha.sf_qpos.astype(jnp.int32)[:, None]
    mask = (off[None, :] < ha.sf_kvlen[:, None]) & (pos <= qp)
    if window > 0:
        mask = mask & (pos > qp - window)
    mask_r = jnp.broadcast_to(mask[:, None, None, :], s.shape)

    s = jnp.where(mask_r, s, MASK_VALUE)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * mask_r
    l = jnp.sum(p, axis=-1)
    u = jnp.einsum("shgn,shnd->shgd", p, vf)
    o = u / jnp.maximum(l, 1e-30)[..., None]

    def unfold(x):
        tail = x.shape[3:]
        return x.reshape(U, n_kv * group, *tail)

    return unfold(o), unfold(m), unfold(l)


@functools.partial(jax.jit, static_argnames=("num_queries", "window"))
def hydragen_partials_arrays(q: jnp.ndarray, k_pool: jnp.ndarray,
                             v_pool: jnp.ndarray, ha: HydragenArrays,
                             num_queries: int, *, window: int = 0
                             ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                        jnp.ndarray]:
    """Both phases + segment-LSE merge -> per-query (o, m, l)."""
    parts_o, parts_m, parts_l, segs = [], [], [], []
    if ha.px_pages.shape[0] > 0:               # static shape: trace-time
        o, m, l = _prefix_phase(q, k_pool, v_pool, ha, window)
        parts_o.append(o); parts_m.append(m); parts_l.append(l)
        segs.append(ha.px_seg)
    if ha.sf_pages.shape[0] > 0:
        o, m, l = _suffix_phase(q, k_pool, v_pool, ha, window)
        parts_o.append(o); parts_m.append(m); parts_l.append(l)
        segs.append(ha.sf_seg)
    if not parts_o:                        # zero-task plan: all-trash
        h_q, d = q.shape[1], q.shape[2]
        parts_o = [jnp.zeros((1, h_q, d), jnp.float32)]
        parts_m = [jnp.full((1, h_q), MASK_VALUE, jnp.float32)]
        parts_l = [jnp.zeros((1, h_q), jnp.float32)]
        segs = [jnp.full((1,), num_queries, jnp.int32)]
    o_parts = jnp.concatenate(parts_o, 0)
    m_parts = jnp.concatenate(parts_m, 0)
    l_parts = jnp.concatenate(parts_l, 0)
    seg_ids = jnp.concatenate(segs, 0)
    return ref_mod.combine_partials_stats_ref(o_parts, m_parts, l_parts,
                                              seg_ids, num_queries)


def hydragen_partials(q, k_pool, v_pool, plan, prepared=None,
                      window: int = 0):
    """Registry entry point (plan + optional cached ``prepare`` output)."""
    if prepared is None:
        prepared = prepare(plan)
    return hydragen_partials_arrays(q, k_pool, v_pool, prepared,
                                    plan.num_queries, window=window)


def hydragen_attention(q, k_pool, v_pool, plan, *, window: int = 0,
                       prepared=None) -> jnp.ndarray:
    """Full decode attention through the Hydragen decomposition."""
    o, _, _ = hydragen_partials(q, k_pool, v_pool, plan, prepared, window)
    return o.astype(q.dtype)
