"""FlashDecoding baseline kernel (dense 4D batch KV layout).

The paper's baseline: decode attention over regular ``(B, L, n_kv, d)``
tensors — each request's KV is read independently, so a shared prefix is
fetched once *per request*.  Implemented as a Pallas TPU kernel with the
same flash accumulators as PAC so kernel-vs-kernel comparisons isolate the
prefix-sharing effect.  (FlashDecoding's split-KV trick exists to create
parallelism across SMs; on TPU the chunk dimension is the sequential grid
axis and batch×head supplies the parallelism, so the split is implicit.)

Note: CoDec with a ``flash_plan`` (every request its own task chain) is the
*plan-level* baseline over the paged pool; this kernel is the *layout-level*
baseline over dense tensors.  Both are exposed to the benchmarks.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import _CompilerParams

MASK_VALUE = -1e30


def _fd_kernel(kvlen_ref,            # scalar prefetch (B,)
               q_ref,                # (1, h_q, d)
               k_ref,                # (1, chunk, n_kv, d)
               v_ref,
               o_ref,                # (1, h_q, d)
               acc, m_s, l_s,        # scratch
               *, n_kv: int, group: int, chunk: int, window: int):
    b = pl.program_id(0)
    c = pl.program_id(1)
    num_chunks = pl.num_programs(1)
    kv_len = kvlen_ref[b]
    start = c * chunk

    @pl.when(c == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)

    @pl.when(start < kv_len)
    def _step():
        h_q, d = q_ref.shape[1], q_ref.shape[2]
        scale = 1.0 / np.sqrt(d)
        q = q_ref[0].astype(jnp.float32)                     # (h_q, d)
        qf = q.reshape(n_kv, group, d)
        kf = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)  # (n_kv, chunk, d)
        vf = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)
        s = jax.lax.dot_general(
            qf, kf, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale       # (n_kv, g, chunk)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        mask = pos < kv_len
        if window > 0:
            mask = mask & (pos > kv_len - 1 - window)
        # rows beyond kv_len may be OOB block padding (NaN): zero V so the
        # (p==0) x NaN product can't poison the accumulator
        vf = jnp.where(mask.reshape(1, chunk, 1), vf, 0.0)
        mask = jnp.broadcast_to(mask[None], s.shape)
        s = jnp.where(mask, s, MASK_VALUE)
        m_new = jnp.maximum(m_s[...], jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask
        alpha = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vf, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc[...] = acc[...] * alpha[..., None] + pv
        m_s[...] = m_new

    @pl.when(c == num_chunks - 1)
    def _finalize():
        h_q, d = q_ref.shape[1], q_ref.shape[2]
        l_safe = jnp.maximum(l_s[...], 1e-30)
        o = acc[...] / l_safe[..., None]                      # (n_kv, g, d)
        o_ref[0] = o.reshape(h_q, d)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "window", "interpret"))
def flash_decode(q: jnp.ndarray,        # (B, h_q, d)
                 k: jnp.ndarray,        # (B, L, n_kv, d)
                 v: jnp.ndarray,
                 kv_lens: jnp.ndarray,  # (B,) int32
                 *, chunk: int = 256, window: int = 0,
                 interpret: bool = True) -> jnp.ndarray:
    B, h_q, d = q.shape
    _, L, n_kv, _ = k.shape
    group = h_q // n_kv
    chunk = min(chunk, L)
    num_chunks = -(-L // chunk)

    kernel = functools.partial(_fd_kernel, n_kv=n_kv, group=group,
                               chunk=chunk, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, num_chunks),
        in_specs=[
            pl.BlockSpec((1, h_q, d), lambda b, c, *_: (b, 0, 0)),
            pl.BlockSpec((1, chunk, n_kv, d), lambda b, c, *_: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, n_kv, d), lambda b, c, *_: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h_q, d), lambda b, c, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, group, d), jnp.float32),
            pltpu.VMEM((n_kv, group), jnp.float32),
            pltpu.VMEM((n_kv, group), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, h_q, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(kv_lens.astype(jnp.int32), q, k, v)
    return out.astype(q.dtype)
