"""CoDec PAC (partial attention computation) Pallas TPU kernel.

One ``pallas_call`` executes the *whole* inter-block schedule (paper §4.3):
the grid is ``(num_lanes, max_steps)`` where a step processes one KV page
of one subtask.  Lanes are the TPU's parallel slots (megacore halves /
sharded cores) — ``dimension_semantics=("parallel", "arbitrary")`` — and
the LPT scheduler balanced work across them; the step dimension executes
sequentially so flash accumulators persist in VMEM scratch across a
subtask's pages.

Memory hierarchy mapping (GPU shared memory -> TPU VMEM):

* K/V pages stream HBM->VMEM through BlockSpec index maps driven by a
  scalar-prefetched page table — the Pallas pipeline double-buffers them;
  *shared-prefix pages are fetched once per subtask regardless of how many
  queries share them* (the paper's central IO saving).
* The per-task query tile (pre-gathered, task-major) is fetched once per
  subtask: consecutive steps with an unchanged block index skip the DMA.
* GQA: Q is folded to ``(n_kv, n_q*group, d)`` so each KV head's page is
  used by all of its query groups in a single MXU pass — the paper's
  GQA-aware load combining.

Outputs are *partial* results ``(o, m, l)`` per (task, query-slot); the
tree reduction (ops.combine_partials) merges them per query.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import _CompilerParams

MASK_VALUE = -1e30


def _pac_kernel(
    # scalar-prefetch refs (num_lanes, max_steps)
    step_task, step_page, step_valid, step_first, step_last,
    step_pos, step_kvlen,
    # operand refs
    q_ref,      # (1, max_q, h_q, d)
    qpos_ref,   # (1, max_q)
    k_ref,      # (1, page, n_kv, d)
    v_ref,      # (1, page, n_kv, d)
    # output refs
    o_ref,      # (1, max_q, h_q, d) f32
    m_ref,      # (1, max_q, h_q)   f32
    l_ref,      # (1, max_q, h_q)   f32
    # scratch
    acc,        # (n_kv, max_q*group, d) f32
    m_s,        # (n_kv, max_q*group)    f32
    l_s,        # (n_kv, max_q*group)    f32
    *,
    n_kv: int,
    group: int,
    window: int,
):
    lane = pl.program_id(0)
    step = pl.program_id(1)
    valid = step_valid[lane, step] == 1
    first = (step_first[lane, step] == 1) & valid
    last = (step_last[lane, step] == 1) & valid

    @pl.when(first)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)

    @pl.when(valid)
    def _step():
        max_q = q_ref.shape[1]
        d = q_ref.shape[3]
        page = k_ref.shape[1]
        scale = 1.0 / np.sqrt(d)

        q = q_ref[0].astype(jnp.float32)            # (max_q, h_q, d)
        # fold GQA: head h = kv*group + g  ->  row = qi*group + g per kv
        qf = (q.reshape(max_q, n_kv, group, d)
                .transpose(1, 0, 2, 3)
                .reshape(n_kv, max_q * group, d))
        kf = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)  # (n_kv, page, d)
        vf = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)

        s = jax.lax.dot_general(
            qf, kf, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale       # (n_kv, R, page)

        # visibility mask (§4.1): page padding + causality + sliding window
        pos = step_pos[lane, step] + jax.lax.broadcasted_iota(
            jnp.int32, (max_q, page), 1)                      # (max_q, page)
        kvlen = step_kvlen[lane, step]
        qp = qpos_ref[0][:, None]                             # (max_q, 1)
        mask = (pos < step_pos[lane, step] + kvlen) & (pos <= qp)
        if window > 0:
            mask = mask & (pos > qp - window)
        mask_r = (jnp.broadcast_to(mask[:, None, :], (max_q, group, page))
                    .reshape(1, max_q * group, page))
        mask_r = jnp.broadcast_to(mask_r, (n_kv, max_q * group, page))

        s = jnp.where(mask_r, s, MASK_VALUE)
        m_new = jnp.maximum(m_s[...], jnp.max(s, axis=-1))    # (n_kv, R)
        p = jnp.exp(s - m_new[..., None]) * mask_r            # masked -> 0
        alpha = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vf, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)               # (n_kv, R, d)
        acc[...] = acc[...] * alpha[..., None] + pv
        m_s[...] = m_new

    @pl.when(last)
    def _finalize():
        max_q = q_ref.shape[1]
        d = q_ref.shape[3]
        l_safe = jnp.maximum(l_s[...], 1e-30)
        o = acc[...] / l_safe[..., None]                      # (n_kv, R, d)
        # unfold GQA back to (max_q, h_q, ...)
        o_ref[0] = (o.reshape(n_kv, max_q, group, d)
                      .transpose(1, 0, 2, 3)
                      .reshape(max_q, n_kv * group, d))
        m_ref[0] = (m_s[...].reshape(n_kv, max_q, group)
                      .transpose(1, 0, 2).reshape(max_q, n_kv * group))
        l_ref[0] = (l_s[...].reshape(n_kv, max_q, group)
                      .transpose(1, 0, 2).reshape(max_q, n_kv * group))


@functools.partial(
    jax.jit,
    static_argnames=("window", "interpret", "num_lanes", "max_steps"))
def pac(q_tasks: jnp.ndarray,       # (T+1, max_q, h_q, d)
        qpos_tasks: jnp.ndarray,    # (T+1, max_q) int32
        k_pool: jnp.ndarray,        # (P, page, n_kv, d)
        v_pool: jnp.ndarray,
        step_task: jnp.ndarray,     # (num_lanes, max_steps) int32
        step_page: jnp.ndarray,
        step_valid: jnp.ndarray,
        step_first: jnp.ndarray,
        step_last: jnp.ndarray,
        step_pos: jnp.ndarray,
        step_kvlen: jnp.ndarray,
        *,
        window: int = 0,
        interpret: bool = True,
        num_lanes: int = 2,
        max_steps: int = 1,
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run the PAC kernel over a compiled DecodePlan's step arrays.

    Returns task-major partials ``(o, m, l)`` of shapes
    ``(T+1, max_q, h_q, d)``, ``(T+1, max_q, h_q)``, ``(T+1, max_q, h_q)``.
    """
    Tp1, max_q, h_q, d = q_tasks.shape
    _, page, n_kv, _ = k_pool.shape
    group = h_q // n_kv
    assert group * n_kv == h_q, (h_q, n_kv)

    grid = (num_lanes, max_steps)

    def q_index(lane, step, st, *_):
        return (st[lane, step], 0, 0, 0)

    def qpos_index(lane, step, st, *_):
        return (st[lane, step], 0)

    def kv_index(lane, step, st, sp, *_):
        return (sp[lane, step], 0, 0, 0)

    def out_index(lane, step, st, *_):
        return (st[lane, step], 0, 0, 0)

    def ml_index(lane, step, st, *_):
        return (st[lane, step], 0, 0)

    kernel = functools.partial(_pac_kernel, n_kv=n_kv, group=group,
                               window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, max_q, h_q, d), q_index),
            pl.BlockSpec((1, max_q), qpos_index),
            pl.BlockSpec((1, page, n_kv, d), kv_index),
            pl.BlockSpec((1, page, n_kv, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, max_q, h_q, d), out_index),
            pl.BlockSpec((1, max_q, h_q), ml_index),
            pl.BlockSpec((1, max_q, h_q), ml_index),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_kv, max_q * group, d), jnp.float32),
            pltpu.VMEM((n_kv, max_q * group), jnp.float32),
            pltpu.VMEM((n_kv, max_q * group), jnp.float32),
        ],
    )

    out_shapes = [
        jax.ShapeDtypeStruct((Tp1, max_q, h_q, d), jnp.float32),
        jax.ShapeDtypeStruct((Tp1, max_q, h_q), jnp.float32),
        jax.ShapeDtypeStruct((Tp1, max_q, h_q), jnp.float32),
    ]

    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(step_task, step_page, step_valid, step_first, step_last,
      step_pos, step_kvlen,
      q_tasks, qpos_tasks, k_pool, v_pool)
    return o, m, l
