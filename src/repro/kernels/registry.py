"""Pluggable decode-attention backend registry.

Every decode-attention implementation is registered here behind one
uniform interface so the engine, benchmarks, and tests resolve backends
by *name* instead of hard-coded ``if/elif`` chains:

    backend = registry.get("hydragen")
    out = backend(q, k_pool, v_pool, plan, window=0)        # (B, h_q, d)

Backends additionally expose ``partials`` — per-query mergeable flash
statistics ``(o, m, l)`` — so the serving engine can POR-merge a
backend's frozen-plan output with its per-step tail-page attention
(see DESIGN.md §3).  ``prepare(plan)`` converts the host ``DecodePlan``
into whatever device arrays the backend consumes; the engine caches the
result across decode steps and only re-runs it on plan rebuilds.

Capability flags let callers pick viable backends per scenario:

* ``needs_plan``       — consumes a compiled ``DecodePlan``;
* ``supports_window``  — honours sliding-window masks (``window > 0``);
* ``supports_gqa``     — handles h_q > n_kv head layouts;
* ``plan_kind``        — which planner the engine must run for it:
  ``"codec"`` (shared-prefix plan) or ``"flash"`` (per-request plan);
* ``shardable``        — the backend's jit-safe partials can trace
  inside the SPMD sharded decode step (``distributed/step_fn.py``):
  they consume only per-shard plan arrays + the local KV pool block,
  so one program instance per device computes that device's partials
  and the engine POR-merges across the mesh.

Registered backends: ``codec-pallas``, ``codec-xla``, ``flash``,
``hydragen``, and the python oracle ``ref``.

Writing a new backend?  ``docs/BACKENDS.md`` is the author guide: the
partials contract, ``prepare``, the jit-safe ``partials_arrays_fn`` /
``advance_fn`` pair, capability flags, and a minimal worked example.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import hydragen as hydragen_mod
from . import ops
from . import ref as ref_mod


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One decode-attention implementation.

    ``partials_fn(q, k_pool, v_pool, plan, prepared, window)`` returns
    per-query flash statistics ``(o, m, l)`` — ``o`` normalised within
    the plan-covered KV — a valid partial for further POR merges.

    **Jit-safe contract** (the fused decode step): backends that can run
    inside a single jitted device program additionally provide

    * ``partials_arrays_fn(q, k_pool, v_pool, prepared, *, num_queries,
      window)`` — like ``partials`` but consuming only the device arrays
      from ``prepare`` (no host ``DecodePlan``); ``num_queries`` and
      ``window`` are trace-time constants, everything else traced;
    * ``advance_fn(prepared, delta)`` — pure-jnp advance of every query
      position by ``delta`` decode steps, so the engine can reuse one
      set of prepared arrays for a whole plan epoch and pass only the
      epoch-relative step counter.

    ``jit_safe`` is derived from their presence; the engine falls back
    to the eager per-layer path for backends without them (``ref``).

    ``shardable`` additionally promises the jit-safe contract holds
    per-shard: ``partials_arrays_fn`` sees only a device-local KV pool
    block and a shard-local plan, and its per-query ``(o, m, l)`` over
    that slice is a valid POR partial (the distributed engine merges
    shards with ``kernels.por.por_allmerge``).
    """

    name: str
    partials_fn: Callable[..., Tuple]
    prepare: Callable[[Any], Any] = ops.plan_arrays
    plan_kind: str = "codec"
    needs_plan: bool = True
    supports_window: bool = True
    supports_gqa: bool = True
    description: str = ""
    partials_arrays_fn: Optional[Callable[..., Tuple]] = None
    advance_fn: Optional[Callable[[Any, Any], Any]] = None
    shardable: bool = False

    @property
    def jit_safe(self) -> bool:
        """Whether the backend can run inside the fused decode step."""
        return (self.partials_arrays_fn is not None
                and self.advance_fn is not None)

    def partials(self, q, k_pool, v_pool, plan, prepared=None, *,
                 window: int = 0):
        """Per-query mergeable (o, m, l) over the plan-covered KV."""
        if window and not self.supports_window:
            raise ValueError(
                f"backend {self.name!r} does not support sliding windows")
        if self.needs_plan and plan is None:
            raise ValueError(
                f"backend {self.name!r} requires a compiled DecodePlan")
        if prepared is None:
            prepared = self.prepare(plan)
        return self.partials_fn(q, k_pool, v_pool, plan, prepared, window)

    def __call__(self, q, k_pool, v_pool, plan, *, window: int = 0,
                 prepared=None) -> jnp.ndarray:
        """Full decode attention: (B, h_q, d) -> (B, h_q, d)."""
        o, _, _ = self.partials(q, k_pool, v_pool, plan, prepared,
                                window=window)
        return o.astype(q.dtype)


_REGISTRY: Dict[str, AttentionBackend] = {}


def register(backend: AttentionBackend) -> AttentionBackend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def names(*, window: Optional[bool] = None,
          gqa: Optional[bool] = None,
          shardable: Optional[bool] = None) -> List[str]:
    """Registered backend names, optionally filtered by capability."""
    out = []
    for n, b in sorted(_REGISTRY.items()):
        if window is not None and b.supports_window != window:
            continue
        if gqa is not None and b.supports_gqa != gqa:
            continue
        if shardable is not None and b.shardable != shardable:
            continue
        out.append(n)
    return out


# --------------------------------------------------------------------- #
# built-in backends
# --------------------------------------------------------------------- #
def _codec_partials(impl: str):
    def fn(q, k_pool, v_pool, plan, pa, window):
        return ops.codec_partials_arrays(q, k_pool, v_pool, pa,
                                         plan.num_queries, window=window,
                                         impl=impl)
    return fn


def _codec_partials_arrays(impl: str):
    def fn(q, k_pool, v_pool, pa, *, num_queries, window):
        return ops.codec_partials_arrays(q, k_pool, v_pool, pa,
                                         num_queries, window=window,
                                         impl=impl)
    return fn


def _hydragen_partials_arrays(q, k_pool, v_pool, ha, *, num_queries,
                              window):
    return hydragen_mod.hydragen_partials_arrays(q, k_pool, v_pool, ha,
                                                 num_queries, window=window)


def _ref_partials(q, k_pool, v_pool, plan, prepared, window):
    return ref_mod.codec_ref_stats(q, k_pool, v_pool, plan, window=window)


register(AttentionBackend(
    name="codec-pallas",
    partials_fn=_codec_partials("pallas"),
    partials_arrays_fn=_codec_partials_arrays("pallas"),
    advance_fn=ops.advance_plan_arrays,
    shardable=True,
    description="CoDec PAC Pallas kernel over the lane-scheduled plan "
                "(interpret mode on CPU, compiled on TPU)"))

register(AttentionBackend(
    name="codec-xla",
    partials_fn=_codec_partials("xla"),
    partials_arrays_fn=_codec_partials_arrays("xla"),
    advance_fn=ops.advance_plan_arrays,
    shardable=True,
    description="CoDec plan semantics as dense vectorised XLA ops "
                "(what the distributed serve_step lowers)"))

register(AttentionBackend(
    name="flash",
    partials_fn=_codec_partials("xla"),
    partials_arrays_fn=_codec_partials_arrays("xla"),
    advance_fn=ops.advance_plan_arrays,
    plan_kind="flash",
    description="FlashDecoding baseline: per-request plan, shared "
                "prefix KV re-read once per request"))

register(AttentionBackend(
    name="hydragen",
    partials_fn=hydragen_mod.hydragen_partials,
    prepare=hydragen_mod.prepare,
    partials_arrays_fn=_hydragen_partials_arrays,
    advance_fn=hydragen_mod.advance,
    description="Hydragen-style batched shared-prefix decomposition: "
                "one dense matmul per shared node for all sharing "
                "queries, per-request suffix attention, LSE merge"))

register(AttentionBackend(
    name="ref",
    partials_fn=_ref_partials,
    prepare=lambda plan: None,
    description="python-loop oracle (slow, exact)"))
