#!/usr/bin/env python
"""Validate exported telemetry artifacts (docs/OBSERVABILITY.md).

Two sub-checks, either or both:

    python tools/check_telemetry.py --trace trace.json
    python tools/check_telemetry.py --metrics metrics.json

Trace check — the file must be a Chrome trace-event JSON object with a
``traceEvents`` list that Perfetto can load:

* every event carries ``name``/``ph``/``pid``/``tid``; ``X`` (complete)
  events also ``ts``/``dur`` with non-negative numbers;
* per ``(pid, tid)`` track, complete events are properly nested: spans
  either contain one another or are disjoint — a pair that partially
  overlaps would render garbage and means a begin/end pairing bug;
* request tracks (pid 2) each close with a terminal instant event.

Metrics check — the file must carry ``schema == "codec-metrics/1"`` and
a ``metrics`` mapping where every entry is a well-formed counter
(non-negative value), gauge, or histogram (bucket counts sum to
``count``, one overflow bucket, non-negative tallies).

Exits non-zero with a per-violation listing, so CI can gate on it.
"""

from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "codec-metrics/1"


def check_trace(path: str) -> list:
    errors = []
    try:
        doc = json.load(open(path))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents list"]
    tracks: dict = {}
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                errors.append(f"event {i}: missing {k!r}: {ev}")
        ph = ev.get("ph")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                errors.append(f"event {i} ({ev.get('name')}): bad ts {ts}")
            elif not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev.get('name')}): bad dur {dur}")
            else:
                tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ts, ts + dur, ev.get("name")))
        elif ph not in ("i", "I", "M", "B", "E"):
            errors.append(f"event {i}: unknown phase {ph!r}")
    for (pid, tid), spans in tracks.items():
        spans.sort()
        for (s0, e0, n0), (s1, e1, n1) in zip(spans, spans[1:]):
            # sorted by start: the later span must nest inside or start
            # after the earlier one — a straddling end is a pairing bug
            if s1 < e0 < e1:
                errors.append(
                    f"track pid={pid} tid={tid}: {n1!r} [{s1},{e1}] "
                    f"partially overlaps {n0!r} [{s0},{e0}]")
    req_tracks = {ev["tid"] for ev in events
                  if ev.get("pid") == 2 and ev.get("ph") == "X"}
    closed = {ev["tid"] for ev in events
              if ev.get("pid") == 2 and ev.get("ph") in ("i", "I")}
    for tid in sorted(req_tracks - closed):
        errors.append(f"request track tid={tid} has spans but never "
                      f"reached a terminal instant")
    if not errors:
        n_x = sum(len(s) for s in tracks.values())
        print(f"{path}: ok — {len(events)} events, {n_x} spans over "
              f"{len(tracks)} tracks, {len(req_tracks)} request tracks")
    return errors


def check_metrics(path: str) -> list:
    errors = []
    try:
        doc = json.load(open(path))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return errors + [f"{path}: no metrics mapping"]
    for name, m in metrics.items():
        t = m.get("type")
        if t == "counter":
            if not isinstance(m.get("value"), (int, float)) \
                    or m["value"] < 0:
                errors.append(f"{name}: counter value {m.get('value')!r}")
        elif t == "gauge":
            if not isinstance(m.get("value"), (int, float)):
                errors.append(f"{name}: gauge value {m.get('value')!r}")
        elif t == "histogram":
            bounds, counts = m.get("bounds"), m.get("counts")
            if not isinstance(bounds, list) or not isinstance(counts, list) \
                    or len(counts) != len(bounds) + 1:
                errors.append(f"{name}: bounds/counts shape mismatch")
            elif any(c < 0 for c in counts) or sum(counts) != m.get("count"):
                errors.append(f"{name}: bucket counts do not sum to "
                              f"count={m.get('count')}")
            elif list(bounds) != sorted(bounds):
                errors.append(f"{name}: bounds not sorted")
        else:
            errors.append(f"{name}: unknown metric type {t!r}")
    if not errors:
        kinds = [m.get("type") for m in metrics.values()]
        print(f"{path}: ok — {len(metrics)} metrics "
              f"({kinds.count('counter')} counters, "
              f"{kinds.count('gauge')} gauges, "
              f"{kinds.count('histogram')} histograms)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default=None,
                    help="codec-metrics/1 JSON to validate")
    args = ap.parse_args()
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")
    errors = []
    if args.trace:
        errors += check_trace(args.trace)
    if args.metrics:
        errors += check_metrics(args.metrics)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
