#!/usr/bin/env python
"""Docs lint: intra-repo links, heading anchors, DESIGN § references.

Checks every tracked markdown file (README.md, DESIGN.md, ROADMAP.md,
docs/*.md) for:

* **relative links** ``[text](path)`` — the target file must exist in
  the repo (external http(s)/mailto links are skipped);
* **anchor links** ``[text](path#anchor)`` / ``[text](#anchor)`` — the
  anchor must match a heading in the target file under GitHub's
  slugification rules;
* **section references** — every textual ``DESIGN.md §N`` mention must
  have a matching ``## §N `` heading in DESIGN.md, so prose references
  can't rot when sections are renumbered;
* **path references** — every backtick-quoted repo path that looks like
  a file (`src/...`, `tests/...`, `docs/...`, `examples/...`,
  `benchmarks/...`, `tools/...`) must exist.

    python tools/check_docs.py        # exit 1 on any broken reference
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted(
    [p for p in ROOT.glob("*.md")] + [p for p in ROOT.glob("docs/*.md")])

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_RE = re.compile(r"DESIGN\.md\s+§(\d+)")
PATH_RE = re.compile(
    r"`((?:src|tests|docs|examples|benchmarks|tools)/[A-Za-z0-9_./-]+"
    r"\.(?:py|md|json|yml))`")


def github_slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)      # drop punctuation (keeps _-)
    return s.replace(" ", "-")


def headings(path: pathlib.Path) -> set:
    out = set()
    for line in path.read_text().splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            out.add(github_slug(m.group(1)))
    return out


def main() -> int:
    errors = []
    design_sections = {
        m.group(1)
        for m in re.finditer(r"^##\s+§(\d+)", (ROOT / "DESIGN.md").read_text(),
                             re.MULTILINE)}
    slug_cache = {}
    for doc in DOCS:
        text = doc.read_text()
        # markdown links
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            tpath = (doc.parent / path_part).resolve() if path_part else doc
            if not tpath.exists():
                errors.append(f"{doc.relative_to(ROOT)}: broken link "
                              f"-> {target}")
                continue
            if anchor and tpath.suffix == ".md":
                if tpath not in slug_cache:
                    slug_cache[tpath] = headings(tpath)
                if anchor not in slug_cache[tpath]:
                    errors.append(
                        f"{doc.relative_to(ROOT)}: missing anchor "
                        f"#{anchor} in {tpath.relative_to(ROOT)}")
        # textual DESIGN § references
        for m in SECTION_RE.finditer(text):
            if m.group(1) not in design_sections:
                errors.append(f"{doc.relative_to(ROOT)}: reference to "
                              f"DESIGN.md §{m.group(1)} but DESIGN.md has "
                              f"no '## §{m.group(1)}' heading")
        # backtick-quoted repo paths
        for m in PATH_RE.finditer(text):
            if not (ROOT / m.group(1)).exists():
                errors.append(f"{doc.relative_to(ROOT)}: path reference "
                              f"`{m.group(1)}` does not exist")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"\n{len(errors)} broken doc reference(s)", file=sys.stderr)
        return 1
    print(f"docs OK: {len(DOCS)} files, {len(design_sections)} DESIGN "
          f"sections")
    return 0


if __name__ == "__main__":
    sys.exit(main())
